"""Original RMT (Bulatov et al. 2022) — the paper's Fig. 2 (left) contrast.

Memory is a sequence of token *embeddings* carried from the FINAL layer's
output of segment s-1 into the INPUT of segment s (eq. 1):

    [_, _, M_s] = Transformer([M_{s-1}, H_s, M_{s-1}])

so cell (s, l) depends on (s-1, L-1) — an inter-layer dependency that makes
the diagonal schedule inapplicable (paper Limitation 1). We implement RMT as
a baseline to *demonstrate* that claim: `rmt_dependencies` is checked against
the diagonal grouping in tests (it violates the DAG), and `run_rmt` only has
a sequential executor.

Layout per segment: [read_mem (M), tokens (T), write_mem (M)]; the write
positions' final-layer outputs become the next segment's read/write memory.
"""
from __future__ import annotations

from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.schedule import StackLayout


def rmt_dependencies(s: int, l: int, n_layers: int) -> List[Tuple[int, int]]:
    """Dependencies of cell (s, l) in the ORIGINAL RMT: within-segment
    layer chain + the final layer of the previous segment (global memory)."""
    deps = []
    if l > 0:
        deps.append((s, l - 1))
    if s > 0:
        deps.append((s - 1, n_layers - 1))   # memory from the LAST layer
    return deps


def diagonal_violates_rmt(n_segments: int, n_layers: int) -> bool:
    """True iff the diagonal grouping breaks an RMT dependency (it always
    does for L >= 2: cell (s, 0) sits in group s but needs (s-1, L-1) from
    group s-1+L-1 > s-1 ... which for L >= 2 is >= s)."""
    from repro.core.schedule import diagonal_groups
    groups = diagonal_groups(n_segments, n_layers)
    level = {}
    for gi, g in enumerate(groups):
        for cell in g:
            level[cell] = gi
    for s in range(n_segments):
        for l in range(n_layers):
            for dep in rmt_dependencies(s, l, n_layers):
                if level[dep] >= level[(s, l)]:
                    return True
    return False


def run_rmt(layout: StackLayout, params, mem0: jax.Array,
            segments: jax.Array, apply_block: Callable,
            *, remat: bool = False):
    """segments: [S, B, T, D]; mem0: [B, M, D] initial memory embeddings.
    Returns (ys [S, B, T, D], final_mem [B, M, D]).

    apply_block(btype, p, x, state) is the same closure the PRMT executors
    use, with empty per-layer state (RMT memory is global, carried here)."""
    M = mem0.shape[1]

    def seg_step(mem, x_tokens):
        x = jnp.concatenate([mem, x_tokens, mem], axis=1)   # [B, M+T+M, D]
        for j, t in enumerate(layout.prelude):
            x, _ = apply_block(t, params["prelude"][j], x, {})
        P = len(layout.pattern)
        if P:
            def sb(xc, sb_params):
                for p, t in enumerate(layout.pattern):
                    xc, _ = apply_block(t, sb_params[p], xc, {})
                return xc, None
            sb_fn = jax.checkpoint(sb) if remat else sb
            x, _ = jax.lax.scan(sb_fn, x, params["pattern"])
        new_mem = x[:, -M:, :]                 # write positions, final layer
        return new_mem, x[:, M:-M, :]

    final_mem, ys = jax.lax.scan(seg_step, mem0, segments)
    return ys, final_mem
