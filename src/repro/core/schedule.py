"""Diagonal-batching schedule as data + the layer-stack layout.

The (segment s, layer l) grid has edges (s,l-1)->(s,l) and (s-1,l)->(s,l)
(layer-local recurrence — PRMT assumption). Diagonal batching executes group
i = { (s,l) : s+l = i }, i = 0..S+L-2, which is minimal (paper Lemma 3.1).

``StackLayout`` describes a heterogeneous layer stack (prelude + repeated
pattern) and gives the static slot-index bookkeeping both executors share.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Pure schedule (for tests / Lemma 3.1 / docs)
# ---------------------------------------------------------------------------

def diagonal_groups(n_segments: int, n_layers: int) -> List[List[Tuple[int, int]]]:
    """Groups of (segment, layer) cells; group i holds cells with s+l == i."""
    groups: List[List[Tuple[int, int]]] = [[] for _ in range(n_segments + n_layers - 1)]
    for s in range(n_segments):
        for l in range(n_layers):
            groups[s + l].append((s, l))
    return groups


def cell_dependencies(s: int, l: int) -> List[Tuple[int, int]]:
    deps = []
    if l > 0:
        deps.append((s, l - 1))
    if s > 0:
        deps.append((s - 1, l))
    return deps


def validate_schedule(groups: List[List[Tuple[int, int]]],
                      n_segments: int, n_layers: int) -> None:
    """Checks a schedule is a valid topological grouping covering every cell."""
    seen = {}
    for gi, group in enumerate(groups):
        for cell in group:
            assert cell not in seen, f"cell {cell} scheduled twice"
            seen[cell] = gi
    assert len(seen) == n_segments * n_layers, "schedule does not cover the grid"
    for (s, l), gi in seen.items():
        for dep in cell_dependencies(s, l):
            assert seen[dep] < gi, f"dependency {dep} of {(s, l)} not satisfied"


def is_minimal(groups, n_segments: int, n_layers: int) -> bool:
    """Lemma 3.1: minimum group count is S+L-1 and each cell sits at s+l."""
    if len([g for g in groups if g]) != n_segments + n_layers - 1:
        return False
    for gi, group in enumerate(groups):
        for (s, l) in group:
            if s + l != gi:
                return False
    return True


# ---------------------------------------------------------------------------
# Suspended-pipeline cursors (resumable diagonal prefill, DESIGN.md §11)
# ---------------------------------------------------------------------------

def n_diagonal_groups(n_segments: int, n_layers: int) -> int:
    """Total anti-diagonal groups of the (S, L) grid — the Lemma 3.1
    minimum, and therefore the step count at which a suspended pipeline
    (core/diagonal.pipeline_step) is complete."""
    return n_segments + n_layers - 1


def segments_completed(step: int, n_segments: int, n_layers: int) -> int:
    """Drain cursor of a suspended pipeline: how many segments have passed
    through every layer after ``step`` anti-diagonal groups (segment s
    finishes at group s + L - 1). Clipped to [0, S] so overshooting the
    final group (the stepper's masked no-op steps) reads as 'all done'."""
    return max(0, min(step - (n_layers - 1), n_segments))


def segments_entered(step: int, n_segments: int, n_layers: int) -> int:
    """Fill cursor of a suspended pipeline: how many segments have been
    inserted into slot 0 after ``step`` groups (segment s enters at group
    s), clipped to the grid."""
    del n_layers
    return max(0, min(step, n_segments))


# ---------------------------------------------------------------------------
# Global-grid cursors (pooled concurrent admissions, DESIGN.md §12)
# ---------------------------------------------------------------------------
#
# With N admissions in flight the scheduler's work set is one *global*
# (request, segment, layer) grid: each member contributes its own (S_r, L)
# sub-grid with an independent group cursor, and a scheduler round executes
# k ready groups from every member plus the decode chunk. These helpers are
# the host-side bookkeeping for that grid — they never read a device cursor
# (the carries' ``step`` scalars stay on device; the host mirrors progress
# from the group budgets it hands out).

def groups_remaining(step: int, n_segments: int, n_layers: int) -> int:
    """Anti-diagonal groups left before a suspended pipeline's grid is
    exhausted; 0 once the cursor overshot (fixed-budget no-op steps and
    pow2 pool pad entries park there)."""
    return max(0, n_diagonal_groups(n_segments, n_layers) - step)


def group_size(i: int, n_segments: int, n_layers: int) -> int:
    """Cells in anti-diagonal group i of an (S, L) grid: the number of
    valid slots at step i (cf. the validity mask in core/diagonal.py)."""
    lo = max(0, i - (n_layers - 1))
    hi = min(n_segments - 1, i)
    return max(0, hi - lo + 1)


def cells_completed(step: int, n_segments: int, n_layers: int) -> int:
    """(segment, layer) cells executed after ``step`` groups — saturates at
    S*L once the grid is done (overshoot groups execute nothing)."""
    return sum(group_size(i, n_segments, n_layers)
               for i in range(max(0, min(step, n_diagonal_groups(
                   n_segments, n_layers)))))


def pool_cells_remaining(steps, segment_counts, n_layers: int) -> int:
    """Unexecuted cells across a pool of suspended carries — the size of
    the global (request, segment, layer) grid still to run. ``steps`` and
    ``segment_counts`` are parallel per-member lists."""
    assert len(steps) == len(segment_counts)
    return sum(S * n_layers - cells_completed(st, S, n_layers)
               for st, S in zip(steps, segment_counts))


# ---------------------------------------------------------------------------
# Stack layout
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StackLayout:
    """prelude layers (individual) followed by `pattern` repeated n_super times.

    Slot l of the diagonal buffer always holds the segment currently entering
    layer l — so slot -> layer-type is static, and grouped application per
    pattern position is a vmap over its n_super stacked layers.
    """
    prelude: Tuple[str, ...]
    pattern: Tuple[str, ...]
    n_super: int

    @property
    def n_layers(self) -> int:
        return len(self.prelude) + len(self.pattern) * self.n_super

    @property
    def layer_types(self) -> Tuple[str, ...]:
        return tuple(self.prelude) + tuple(self.pattern) * self.n_super

    def position_slots(self, p: int) -> np.ndarray:
        """Global slot indices of pattern position p across superblocks."""
        base = len(self.prelude)
        P = len(self.pattern)
        return base + p + P * np.arange(self.n_super)

    @staticmethod
    def from_config(cfg) -> "StackLayout":
        return StackLayout(prelude=tuple(cfg.prelude),
                           pattern=tuple(cfg.block_pattern),
                           n_super=cfg.n_superblocks)
