"""Sequential PRMT executor — the paper's baseline schedule.

Processes segments strictly in order; within a segment, layers run in order
(scan over superblocks, static loop over the pattern). This is the
``n_segments x n_layers`` serialized schedule of paper Fig. 3a.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.memory import recurrent_state
from repro.core.schedule import StackLayout

# apply_block(btype, layer_params, x, layer_state) -> (y, new_layer_state)
ApplyBlock = Callable[[str, Any, jax.Array, Any], tuple]


def run_sequential(layout: StackLayout, params: Dict, state0: Dict,
                   segments: jax.Array, apply_block: ApplyBlock,
                   *, remat: bool = False, capture_states: bool = False):
    """segments: [S, B, T, D] -> (ys [S, B, T, D], final_state).

    params/state structure:
      {'prelude': tuple(len n_prelude) of per-layer pytrees,
       'pattern': tuple(len P) of pytrees stacked over n_super on axis 0}

    capture_states: also return, as a third output, the recurrent state
    (A/z/h/conv) after every segment with leading axis [S] — in the
    sequential schedule each scan step's state *is* the segment-boundary
    state, so unlike the diagonal executor no reindexing is needed.
    """
    P = len(layout.pattern)

    def superblock(x, sb):
        sb_params, sb_state = sb
        new_states = []
        for p, t in enumerate(layout.pattern):
            x, st = apply_block(t, sb_params[p], x, sb_state[p])
            new_states.append(st)
        return x, tuple(new_states)

    sb_fn = jax.checkpoint(superblock) if remat else superblock

    def seg_step(states, x):
        new_prelude = []
        for j, t in enumerate(layout.prelude):
            x, st = apply_block(t, params["prelude"][j], x, states["prelude"][j])
            new_prelude.append(st)
        if P:
            def scan_body(carry_x, sb):
                return sb_fn(carry_x, sb)
            x, new_pattern = jax.lax.scan(
                scan_body, x, (params["pattern"], states["pattern"]))
        else:
            new_pattern = states["pattern"]
        new_states = {"prelude": tuple(new_prelude), "pattern": new_pattern}
        emit = (x, recurrent_state(new_states)) if capture_states else x
        return new_states, emit

    final_state, emitted = jax.lax.scan(seg_step, state0, segments)
    if capture_states:
        ys, captured = emitted
        return ys, final_state, captured
    return emitted, final_state
