"""Core: the paper's contribution — ARMT associative memory + the diagonal
batching schedule/executors. See DESIGN.md §2-3."""
from repro.core.schedule import (StackLayout, diagonal_groups, is_minimal,
                                 validate_schedule, cell_dependencies)
from repro.core.memory import (dpfp, d_phi, mem_param_init, mem_state_init,
                               mem_read, mem_update, recurrent_state,
                               RECURRENT_KEYS)
from repro.core.sequential import run_sequential
from repro.core.diagonal import run_diagonal, boundary_states_from_capture
