"""ARMT associative memory (paper eqs. 3-6).

Per layer l the memory is an associative matrix A^l in R^{d_phi x d_val} and a
normalizer z^l in R^{d_phi}, with d_phi = 2*nu*d_mem (DPFP-nu feature map,
nu=3 in the paper -> 6*d_mem). Once per segment:

  read (eq 6):    AssociativeLayer(x) = A phi(W_Q x) / (z^T phi(W_Q x))
  update (3-5):   k,v = W_K m, W_V m;  beta = sigmoid(W_beta m)
                  vbar  = A phi(k) / (z^T phi(k))
                  gamma = 1 - z^T phi(k) / ||phi(k)||^2
                  A <- A + sum_i beta_i (v_i - vbar_i) (x) phi(k_i)
                  z <- z + sum_i gamma_i phi(k_i)

The read is applied residually to every position of the segment input; the
update uses the transformer-layer *outputs* at the memory-token positions.
State is kept in float32 regardless of model dtype (cheap: d_phi*d_val per
layer) — numerics note in DESIGN.md §7.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs import ARMTConfig

EPS = 1e-6

# The per-layer *recurrent* state leaves: ARMT associative memory (A, z) and
# SSM carry (h, conv). This — plus an in-segment position — is everything a
# segment-boundary snapshot needs: KV caches are segment-local (reset at every
# flush) and so are empty at a boundary by construction. The serving state
# store (serve/state_store.py) and the decode-state transplant both key off
# this list, so it lives here next to the memory math.
RECURRENT_KEYS = ("A", "z", "h", "conv")


def recurrent_state(state: Dict) -> Dict:
    """Project an executor/decode state tree onto its recurrent leaves.

    state: {'prelude': tuple of per-layer dicts, 'pattern': tuple of stacked
    dicts} (extra keys like 'pos' or caches are ignored). Returns the same
    structure with only RECURRENT_KEYS kept per layer — the constant-size
    summary of the whole prefix that makes segment-granular prefix caching
    kilobytes instead of a KV-cache's gigabytes."""
    def keep(d: Dict) -> Dict:
        return {k: d[k] for k in RECURRENT_KEYS if k in d}
    return {"prelude": tuple(keep(d) for d in state["prelude"]),
            "pattern": tuple(keep(d) for d in state["pattern"])}


def dpfp(x: jax.Array, nu: int = 3) -> jax.Array:
    """Deterministic Parameter-Free Projection (Schlag et al. 2021).

    x: [..., d]  ->  [..., 2*nu*d], elementwise non-negative.
    """
    r = jnp.concatenate([jax.nn.relu(x), jax.nn.relu(-x)], axis=-1)  # [..., 2d]
    parts = [r * jnp.roll(r, shift=j, axis=-1) for j in range(1, nu + 1)]
    return jnp.concatenate(parts, axis=-1)


def d_phi(acfg: ARMTConfig) -> int:
    return 2 * acfg.nu * acfg.d_mem


def mem_param_init(key: jax.Array, d_model: int, acfg: ARMTConfig,
                   dtype=jnp.float32) -> Dict[str, jax.Array]:
    d_val = acfg.d_val or d_model
    kq, kk, kv, kb = jax.random.split(key, 4)
    s = d_model ** -0.5
    return {
        "wq": (jax.random.normal(kq, (d_model, acfg.d_mem)) * s).astype(dtype),
        "wk": (jax.random.normal(kk, (d_model, acfg.d_mem)) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d_model, d_val)) * s).astype(dtype),
        "wb": (jax.random.normal(kb, (d_model, 1)) * s).astype(dtype),
    }


def state_dtype(x_dtype) -> jnp.dtype:
    """Memory state is kept at >= fp32 (fp64 under x64 for exactness tests)."""
    return jnp.result_type(x_dtype, jnp.float32)


def mem_state_init(batch: int, d_model: int, acfg: ARMTConfig,
                   dtype=jnp.float32) -> Dict[str, jax.Array]:
    """Zero state (eq 3: A_0 = 0, z_0 = 0)."""
    d_val = acfg.d_val or d_model
    dt = state_dtype(dtype)
    return {
        "A": jnp.zeros((batch, d_phi(acfg), d_val), dt),
        "z": jnp.zeros((batch, d_phi(acfg)), dt),
    }


def mem_read(params: Dict[str, jax.Array], state: Dict[str, jax.Array],
             x: jax.Array, acfg: ARMTConfig) -> jax.Array:
    """Associative read (eq 6). x: [B, T, D] -> [B, T, d_val] (fp32+ math)."""
    dt = state_dtype(x.dtype)
    q = jnp.einsum("btd,dm->btm", x.astype(dt), params["wq"].astype(dt))
    pq = dpfp(q, acfg.nu)                                        # [B,T,P]
    num = jnp.einsum("btp,bpv->btv", pq, state["A"])
    den = jnp.einsum("btp,bp->bt", pq, state["z"]) + EPS
    return (num / den[..., None]).astype(x.dtype)


def mem_update(params: Dict[str, jax.Array], state: Dict[str, jax.Array],
               m: jax.Array, acfg: ARMTConfig) -> Dict[str, jax.Array]:
    """Delta-rule update (eqs 3-5). m: [B, M, D] memory-token layer outputs."""
    dt = state_dtype(m.dtype)
    m32 = m.astype(dt)
    k = jnp.einsum("bmd,de->bme", m32, params["wk"].astype(dt))
    v = jnp.einsum("bmd,dv->bmv", m32, params["wv"].astype(dt))
    beta = jax.nn.sigmoid(
        jnp.einsum("bmd,do->bmo", m32, params["wb"].astype(dt)))[..., 0]
    pk = dpfp(k, acfg.nu)                                        # [B,M,P]
    zk = jnp.einsum("bmp,bp->bm", pk, state["z"])                # z^T phi(k)
    vbar = jnp.einsum("bmp,bpv->bmv", pk, state["A"]) / (zk + EPS)[..., None]
    gamma = 1.0 - zk / (jnp.sum(pk * pk, axis=-1) + EPS)
    A_new = state["A"] + jnp.einsum("bm,bmv,bmp->bpv", beta, v - vbar, pk)
    z_new = state["z"] + jnp.einsum("bm,bmp->bp", gamma, pk)
    return {"A": A_new, "z": z_new}
