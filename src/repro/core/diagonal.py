"""Diagonal-batching executor (the paper's contribution, paper Alg. 1).

Carries a slot buffer ``buf[L, B, T, D]`` with the invariant *slot l holds the
segment currently entering layer l*. Each scan step executes one anti-diagonal:
every slot advances one layer via a single grouped application per pattern
position — either ``jax.vmap(apply_block)`` (the exactness oracle) or the
fused grouped-kernel path (``grouped_apply``, models/grouped_blocks.py), the
TPU analogue of the paper's CUTLASS GroupedGEMM + batched-attention launch —
then the buffer shifts down one slot.

S + L - 1 steps total (minimal, Lemma 3.1); recurrence is exact: per-layer
states are updated by the same functions in the same order as the sequential
executor, only grouped across slots.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.memory import recurrent_state
from repro.core.schedule import StackLayout

ApplyBlock = Callable[[str, Any, jax.Array, Any], tuple]


def _mask_state(valid, new, old):
    """Keep old state where the slot was invalid (pipeline fill/drain)."""
    def sel(n, o):
        v = valid.reshape(valid.shape + (1,) * (n.ndim - valid.ndim))
        return jnp.where(v, n, o)
    return jax.tree_util.tree_map(sel, new, old)


def boundary_states_from_capture(layout: StackLayout, captured: Dict,
                                 n_segments: int) -> Dict:
    """Assemble per-segment-boundary recurrent states from a diagonal run's
    per-step capture (run_diagonal(capture_states=True)).

    In the diagonal schedule, layer at slot l processes segment s at step
    s + l — so the state "prefix through segment c" is spread across steps:
    layer l's piece of boundary c was emitted at step (c-1) + l. This gathers
    those pieces into a tree whose leaves lead with a boundary axis [S, ...]
    (boundary c at index c-1), entirely device-side: one gather per leaf, no
    host transfer until the caller moves a snapshot off-device.
    """
    S = n_segments
    steps = jnp.arange(S)
    prelude = tuple(
        jax.tree_util.tree_map(lambda a, _j=j: a[steps + _j], captured["prelude"][j])
        for j in range(len(layout.prelude)))
    pattern = []
    for p in range(len(layout.pattern)):
        slots = jnp.asarray(layout.position_slots(p))            # [n_super]
        idx = steps[:, None] + slots[None, :]                    # [S, n_super]
        sup = jnp.arange(len(slots))[None, :]
        pattern.append(jax.tree_util.tree_map(
            lambda a: a[idx, sup], captured["pattern"][p]))
    return {"prelude": prelude, "pattern": tuple(pattern)}


def run_diagonal(layout: StackLayout, params: Dict, state0: Dict,
                 segments: jax.Array, apply_block: ApplyBlock,
                 *, remat: bool = False, buf_spec=None, grouped_apply=None,
                 capture_states: bool = False):
    """segments: [S, B, T, D] -> (ys [S, B, T, D], final_state).

    Same params/state structure as run_sequential — the two executors are
    interchangeable (that is the point of the paper: pure reordering).

    buf_spec: optional PartitionSpec for the slot buffer [L, B, T, D]. With
    the slot dim sharded over a mesh axis ('stage'), diagonal batching
    *becomes pipeline parallelism*: every stage applies its own layers with
    fully local weights and the shift lowers to one collective-permute per
    step — no per-layer tensor-parallel all-reduces (EXPERIMENTS.md §Perf).

    grouped_apply: optional fused grouped-block application
    ``(btype, stacked_params [n_super, ...], x [n_super, B, T, D],
    stacked_state) -> (y, new_state)`` replacing the default
    ``jax.vmap(apply_block)`` over each pattern position — the fast mode
    built by ``models.grouped_blocks.make_grouped_apply`` that launches the
    Pallas grouped kernels (grouped GEMM / batched flash attention / fused
    ARMT memory) over the whole group (EXPERIMENTS.md §Perf).

    capture_states: also return the per-step recurrent state (A/z/h/conv)
    of every layer as a third output with leading axis [S+L-1] — the raw
    material for segment-boundary snapshots (boundary_states_from_capture,
    serve/state_store.py). Constant-size per step, so the extra scan output
    is (S+L-1) x the recurrent-state footprint, not activations.
    """
    S = segments.shape[0]
    L = layout.n_layers
    P = len(layout.pattern)
    n_steps = S + L - 1
    n_pre = len(layout.prelude)

    pad = jnp.zeros((L - 1,) + segments.shape[1:], segments.dtype)
    xs_seg = jnp.concatenate([segments, pad], axis=0) if L > 1 else segments
    slot_ids = jnp.arange(L)

    pos_slots = [np.asarray(layout.position_slots(p)) for p in range(P)]

    def _constrain(b):
        if buf_spec is not None:
            return jax.lax.with_sharding_constraint(b, buf_spec)
        return b

    slot_axis = buf_spec[0] if buf_spec is not None else None
    batch_axis = (buf_spec[1] if buf_spec is not None and len(buf_spec) > 1
                  else None)

    def _constrain_states(pattern_states):
        """Pin per-layer recurrent state (A/z/h/conv) to the slot sharding —
        otherwise GSPMD re-gathers the stage-sharded activations every step.
        State layout is [n_super, B, ...]: slot axis on dim 0, the buffer's
        batch axis on dim 1."""
        if slot_axis is None:
            return pattern_states
        from jax.sharding import PartitionSpec as PS

        def one(leaf):
            rest = [None] * (leaf.ndim - 1)
            if leaf.ndim >= 2 and batch_axis is not None:
                rest[0] = batch_axis
            return jax.lax.with_sharding_constraint(
                leaf, PS(slot_axis, *rest))
        return tuple(jax.tree_util.tree_map(one, st) for st in pattern_states)

    def diag_step(carry, xs):
        buf, states = carry
        seg_in, i = xs
        # insert the new segment into slot 0 with an elementwise select (an
        # indexed write would re-layout the stage-sharded slot dim — the
        # select is local on every shard; seg_in is replicated over 'stage')
        is0 = (slot_ids == 0)[(...,) + (None,) * (buf.ndim - 1)]
        buf = _constrain(jnp.where(is0, seg_in[None].astype(buf.dtype), buf))
        # slot l holds segment i - l; valid iff 0 <= i - l < S. Clear invalid
        # fill/drain slots with a select, NOT a multiply: an inf/NaN produced
        # by a block applied to empty padding would survive `0 * inf = nan`
        # and poison any group-coupled application (grouped kernels, global
        # MoE dispatch) on the next step.
        valid = (i >= slot_ids) & (i - slot_ids < S)                     # [L]
        valid_b = valid[(...,) + (None,) * (buf.ndim - 1)]
        buf = jnp.where(valid_b, buf, jnp.zeros_like(buf))

        y = jnp.zeros_like(buf)
        new_prelude = []
        for j, t in enumerate(layout.prelude):
            yj, stj = apply_block(t, params["prelude"][j], buf[j],
                                  states["prelude"][j])
            y = y.at[j].set(yj)
            new_prelude.append(_mask_state(valid[j], stj, states["prelude"][j]))

        new_pattern = []
        for p, t in enumerate(layout.pattern):
            slots = pos_slots[p]
            contiguous = P == 1          # slots are base..base+n_super-1
            if contiguous:
                # plain slice: SPMD-transparent (a fancy-indexed gather would
                # all-gather the stage-sharded buffer every step)
                xp = jax.lax.slice_in_dim(buf, int(slots[0]),
                                          int(slots[0]) + len(slots), axis=0)
            else:
                xp = buf[slots]                               # [n_super, B, T, D]
            if grouped_apply is not None:
                yp, stp = grouped_apply(t, params["pattern"][p], xp,
                                        states["pattern"][p])
            else:
                grouped = jax.vmap(
                    lambda pp, xx, ss, _t=t: apply_block(_t, pp, xx, ss))
                yp, stp = grouped(params["pattern"][p], xp,
                                  states["pattern"][p])
            if contiguous:
                y = jax.lax.dynamic_update_slice_in_dim(
                    y, yp.astype(y.dtype), int(slots[0]), axis=0)
            else:
                y = y.at[slots].set(yp)
            new_pattern.append(
                _mask_state(valid[slots], stp, states["pattern"][p]))
        new_pattern = _constrain_states(tuple(new_pattern))

        out = y[L - 1]                      # segment i-(L-1) finished all layers
        y = _constrain(y)
        # shift as a roll: on a stage-sharded slot dim this lowers to ONE
        # boundary collective-permute instead of an all-gather of the buffer
        buf_next = jnp.roll(y, shift=1, axis=0)
        is0 = (slot_ids == 0)[(...,) + (None,) * (y.ndim - 1)]
        buf_next = _constrain(jnp.where(is0, jnp.zeros_like(buf_next),
                                        buf_next))
        new_states = {"prelude": tuple(new_prelude), "pattern": tuple(new_pattern)}
        emit = ((out, recurrent_state(new_states)) if capture_states
                else out)
        return (buf_next, new_states), emit

    step_fn = jax.checkpoint(diag_step) if remat else diag_step

    buf0 = _constrain(jnp.zeros((L,) + segments.shape[1:], segments.dtype))
    state0 = dict(state0,
                  pattern=_constrain_states(tuple(state0["pattern"])))
    (_, final_state), emitted = jax.lax.scan(
        step_fn, (buf0, state0), (xs_seg, jnp.arange(n_steps)))
    if capture_states:
        ys, captured = emitted
        return ys[L - 1:], final_state, captured
    return emitted[L - 1:], final_state
