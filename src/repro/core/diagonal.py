"""Diagonal-batching executor (the paper's contribution, paper Alg. 1).

Carries a slot buffer ``buf[L, B, T, D]`` with the invariant *slot l holds the
segment currently entering layer l*. Each scan step executes one anti-diagonal:
every slot advances one layer via a single grouped application per pattern
position — either ``jax.vmap(apply_block)`` (the exactness oracle) or the
fused grouped-kernel path (``grouped_apply``, models/grouped_blocks.py), the
TPU analogue of the paper's CUTLASS GroupedGEMM + batched-attention launch —
then the buffer shifts down one slot.

S + L - 1 steps total (minimal, Lemma 3.1); recurrence is exact: per-layer
states are updated by the same functions in the same order as the sequential
executor, only grouped across slots.

Two drivers share one anti-diagonal step body (``_diag_body``):

  * ``run_diagonal`` — the one-shot executor: a single ``lax.scan`` over all
    S + L - 1 groups (training / blocking prefill).
  * ``pipeline_init`` / ``pipeline_step`` / ``pipeline_finalize`` — the
    *resumable* pipeline (DESIGN.md §11): the carry (slot buffer, executor
    state, group cursor, per-segment output buffer, optional recurrent-state
    capture) is explicit, and each ``pipeline_step`` call advances a bounded
    number of groups, so a long prefill can be suspended between calls —
    e.g. to let decode chunks run (serve/scheduler.py) — and resumed
    bit-exactly. Sharing the step body is what makes the two drivers
    token-identical by construction. ``pipeline_step_pool`` batches N such
    carries (with independent cursors) into one launch for the scheduler's
    pooled concurrent admissions (DESIGN.md §12).
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.memory import recurrent_state
from repro.core.schedule import StackLayout

ApplyBlock = Callable[[str, Any, jax.Array, Any], tuple]


def _mask_state(valid, new, old):
    """Keep old state where the slot was invalid (pipeline fill/drain)."""
    def sel(n, o):
        v = valid.reshape(valid.shape + (1,) * (n.ndim - valid.ndim))
        return jnp.where(v, n, o)
    return jax.tree_util.tree_map(sel, new, old)


def boundary_states_from_capture(layout: StackLayout, captured: Dict,
                                 n_segments: int) -> Dict:
    """Assemble per-segment-boundary recurrent states from a diagonal run's
    per-step capture (run_diagonal(capture_states=True)).

    In the diagonal schedule, layer at slot l processes segment s at step
    s + l — so the state "prefix through segment c" is spread across steps:
    layer l's piece of boundary c was emitted at step (c-1) + l. This gathers
    those pieces into a tree whose leaves lead with a boundary axis [S, ...]
    (boundary c at index c-1), entirely device-side: one gather per leaf, no
    host transfer until the caller moves a snapshot off-device.
    """
    S = n_segments
    steps = jnp.arange(S)
    prelude = tuple(
        jax.tree_util.tree_map(lambda a, _j=j: a[steps + _j], captured["prelude"][j])
        for j in range(len(layout.prelude)))
    pattern = []
    for p in range(len(layout.pattern)):
        slots = jnp.asarray(layout.position_slots(p))            # [n_super]
        idx = steps[:, None] + slots[None, :]                    # [S, n_super]
        sup = jnp.arange(len(slots))[None, :]
        pattern.append(jax.tree_util.tree_map(
            lambda a: a[idx, sup], captured["pattern"][p]))
    return {"prelude": prelude, "pattern": tuple(pattern)}


def _spec_axes(buf_spec):
    slot_axis = buf_spec[0] if buf_spec is not None else None
    batch_axis = (buf_spec[1] if buf_spec is not None and len(buf_spec) > 1
                  else None)
    return slot_axis, batch_axis


def _constrain_fn(buf_spec):
    def _constrain(b):
        if buf_spec is not None:
            return jax.lax.with_sharding_constraint(b, buf_spec)
        return b
    return _constrain


def _constrain_states_fn(buf_spec):
    """Pin per-layer recurrent state (A/z/h/conv) to the slot sharding —
    otherwise GSPMD re-gathers the stage-sharded activations every step.
    State layout is [n_super, B, ...]: slot axis on dim 0, the buffer's
    batch axis on dim 1."""
    slot_axis, batch_axis = _spec_axes(buf_spec)

    def _constrain_states(pattern_states):
        if slot_axis is None:
            return pattern_states
        from jax.sharding import PartitionSpec as PS

        def one(leaf):
            rest = [None] * (leaf.ndim - 1)
            if leaf.ndim >= 2 and batch_axis is not None:
                rest[0] = batch_axis
            return jax.lax.with_sharding_constraint(
                leaf, PS(slot_axis, *rest))
        return tuple(jax.tree_util.tree_map(one, st) for st in pattern_states)

    return _constrain_states


def _band_phases(S: int, L: int):
    """Partition the S+L-1 anti-diagonal steps into phases of constant
    *valid-slot band* size, for the banded fused driver.

    At step i the valid slots form the contiguous band
    [max(0, i-S+1), min(i, L-1)] — everything outside is fill/drain padding
    the full-width body computes and throws away (up to (S+L-1)/S x wasted
    cell-applies at small S). Each phase is ``(i0, n_steps, Gb, mode)``:
    ``n_steps`` consecutive steps whose band is exactly ``Gb`` slots wide.
    Exact widths mean the banded schedule executes exactly S*L cell
    applies — the sequential executor's count — at the cost of
    2*min(S, L) - 1 compiled step bodies (bounded by the layer count; the
    earlier pow2 bucketing halved the body count but re-ran up to ~20%
    padded cells, which is the wrong trade on every measured shape).

    mode: 'fill' (band [0, i], growing one slot per step), 'drain' (band
    ends at slot L-1, shrinking), 'mid' (constant width min(S, L): the
    full stack when S >= L, else a band sliding with i).
    """
    m = min(S, L)
    phases = []
    for i in range(m - 1):                 # fill: band [0, i], width i+1
        phases.append((i, 1, i + 1, "fill"))
    phases.append((m - 1, max(S, L) - (m - 1), m, "mid"))
    last = S + L - 2
    for i in range(max(S, L), last + 1):   # drain: band [i-S+1, L-1]
        phases.append((i, 1, S + L - 1 - i, "drain"))
    return phases


def _diag_body(layout: StackLayout, params: Dict, apply_block: ApplyBlock,
               n_segments: int, *, buf_spec=None, grouped_apply=None,
               capture_states: bool = False, band=None):
    """One anti-diagonal group as a pure step function

        body((buf, states), (seg_in, i)) -> ((buf_next, states_next), emit)

    shared — the same closure, hence the same math in the same order — by
    the one-shot scan executor (run_diagonal) and the resumable pipeline
    stepper (pipeline_step). ``emit`` is the drained slot's output (plus
    the per-step recurrent-state capture when capture_states). Groups with
    ``i`` outside [0, S+L-2] are masked no-ops on the executor state: every
    slot is invalid, so states freeze and only the (ignored) buffer churns.

    ``band=(Gb, mode)`` (single-position patterns, no prelude, no buf_spec)
    selects the *banded* body: only a ``Gb``-slot slice around the valid
    band is applied (``_band_phases``), skipping fill/drain padding compute.
    Valid slots see identical inputs/params/state as the full-width body —
    the per-slot math is group-size-independent — so outputs and state
    updates are unchanged (tests/test_executors.py::test_banded_*).
    """
    S = n_segments
    L = layout.n_layers
    P = len(layout.pattern)
    slot_ids = jnp.arange(L)
    pos_slots = [np.asarray(layout.position_slots(p)) for p in range(P)]
    _constrain = _constrain_fn(buf_spec)
    _constrain_states = _constrain_states_fn(buf_spec)

    if band is not None:
        assert P == 1 and not layout.prelude and buf_spec is None, (
            "banded body needs a single-position pattern, no prelude and "
            "no slot sharding")
        Gb, mode = band
        t0 = layout.pattern[0]
        # With exact band widths (_band_phases) every slot in the band is
        # valid, so the body touches ONLY the band: no full-buffer
        # seg-insert/validity selects, no full-width y materialization, no
        # roll — the write target shifts one slot instead (y[l] lives at
        # buf[l+1] next step; slots outside the write are zero or stale
        # never-again-read fill residue). That drops the driver overhead
        # from ~5 full [L,B,T,D] passes per step to ~1.
        sliding = mode == "mid" and S < L      # band [i-S+1, i], start moves

        def banded_step(carry, xs):
            with jax.named_scope("diag.antidiagonal_banded"):
                buf, states = carry
                seg_in, i = xs
                if mode == "drain":
                    start = L - Gb
                elif sliding:
                    start = jnp.maximum(i - Gb + 1, 0)
                else:                          # fill / full-width mid
                    start = 0

                def sl(a):
                    return jax.lax.dynamic_slice_in_dim(a, start, Gb, axis=0)

                xb = sl(buf)
                if mode != "drain":
                    # slot 0 takes the entering segment; it is in the band
                    # exactly when start == 0 (static for fill/full mid,
                    # first step only of a sliding mid)
                    seg = seg_in.astype(buf.dtype)
                    if sliding:
                        row0 = jnp.where(start == 0, seg, xb[0])
                    else:
                        row0 = seg
                    xb = jnp.concatenate([row0[None], xb[1:]], axis=0)
                pb = jax.tree_util.tree_map(sl, params["pattern"][0])
                sb = jax.tree_util.tree_map(sl, states["pattern"][0])
                if grouped_apply is not None:
                    yb, stb = grouped_apply(t0, pb, xb, sb)
                else:
                    grouped = jax.vmap(
                        lambda pp, xx, ss: apply_block(t0, pp, xx, ss))
                    yb, stb = grouped(pb, xb, sb)
                new_p = jax.tree_util.tree_map(
                    lambda full, b: jax.lax.dynamic_update_slice_in_dim(
                        full, b.astype(full.dtype), start, axis=0),
                    states["pattern"][0], stb)
                new_states = {"prelude": states["prelude"],
                              "pattern": (new_p,)}

                yb = yb.astype(buf.dtype)
                if mode == "fill":
                    # band top is at most L-2: the whole band shifts down
                    out = jnp.zeros_like(buf[0])    # no drain yet (discarded)
                    buf_next = jax.lax.dynamic_update_slice_in_dim(
                        jnp.zeros_like(buf), yb, 1, axis=0)
                elif sliding:
                    # drain emission only on the step whose band top is L-1
                    out = jnp.where(start == L - Gb, yb[-1],
                                    jnp.zeros_like(yb[-1]))
                    # scatter into an (L+1)-row buffer so start+1 == L-Gb+1
                    # (the last sliding step) stays in bounds, then trim
                    buf_next = jax.lax.dynamic_update_slice_in_dim(
                        jnp.zeros((L + 1,) + buf.shape[1:], buf.dtype),
                        yb, start + 1, axis=0)[:L]
                else:
                    # drain / full-width mid: band top is L-1 — its output
                    # drains out of the pipeline as this step's emission
                    out = yb[-1]
                    buf_next = jax.lax.dynamic_update_slice_in_dim(
                        jnp.zeros_like(buf), yb[:-1], start + 1, axis=0)
                emit = ((out, recurrent_state(new_states)) if capture_states
                        else out)
                return (buf_next, new_states), emit

        return banded_step

    def diag_step(carry, xs):
        # named_scope: the anti-diagonal group shows up as one labeled
        # region in XLA profiles, matching the serve stack's host spans
        # (DESIGN.md §13)
        with jax.named_scope("diag.antidiagonal"):
            return _diag_step(carry, xs)

    def _diag_step(carry, xs):
        buf, states = carry
        seg_in, i = xs
        # insert the new segment into slot 0 with an elementwise select (an
        # indexed write would re-layout the stage-sharded slot dim — the
        # select is local on every shard; seg_in is replicated over 'stage')
        is0 = (slot_ids == 0)[(...,) + (None,) * (buf.ndim - 1)]
        buf = _constrain(jnp.where(is0, seg_in[None].astype(buf.dtype), buf))
        # slot l holds segment i - l; valid iff 0 <= i - l < S. Clear invalid
        # fill/drain slots with a select, NOT a multiply: an inf/NaN produced
        # by a block applied to empty padding would survive `0 * inf = nan`
        # and poison any group-coupled application (grouped kernels, global
        # MoE dispatch) on the next step.
        valid = (i >= slot_ids) & (i - slot_ids < S)                     # [L]
        valid_b = valid[(...,) + (None,) * (buf.ndim - 1)]
        buf = jnp.where(valid_b, buf, jnp.zeros_like(buf))

        y = jnp.zeros_like(buf)
        new_prelude = []
        for j, t in enumerate(layout.prelude):
            yj, stj = apply_block(t, params["prelude"][j], buf[j],
                                  states["prelude"][j])
            y = y.at[j].set(yj)
            new_prelude.append(_mask_state(valid[j], stj, states["prelude"][j]))

        new_pattern = []
        for p, t in enumerate(layout.pattern):
            slots = pos_slots[p]
            contiguous = P == 1          # slots are base..base+n_super-1
            if contiguous:
                # plain slice: SPMD-transparent (a fancy-indexed gather would
                # all-gather the stage-sharded buffer every step)
                xp = jax.lax.slice_in_dim(buf, int(slots[0]),
                                          int(slots[0]) + len(slots), axis=0)
            else:
                xp = buf[slots]                               # [n_super, B, T, D]
            if grouped_apply is not None:
                yp, stp = grouped_apply(t, params["pattern"][p], xp,
                                        states["pattern"][p])
            else:
                grouped = jax.vmap(
                    lambda pp, xx, ss, _t=t: apply_block(_t, pp, xx, ss))
                yp, stp = grouped(params["pattern"][p], xp,
                                  states["pattern"][p])
            if contiguous:
                y = jax.lax.dynamic_update_slice_in_dim(
                    y, yp.astype(y.dtype), int(slots[0]), axis=0)
            else:
                y = y.at[slots].set(yp)
            new_pattern.append(
                _mask_state(valid[slots], stp, states["pattern"][p]))
        new_pattern = _constrain_states(tuple(new_pattern))

        out = y[L - 1]                      # segment i-(L-1) finished all layers
        y = _constrain(y)
        # shift as a roll: on a stage-sharded slot dim this lowers to ONE
        # boundary collective-permute instead of an all-gather of the buffer
        buf_next = jnp.roll(y, shift=1, axis=0)
        is0 = (slot_ids == 0)[(...,) + (None,) * (y.ndim - 1)]
        buf_next = _constrain(jnp.where(is0, jnp.zeros_like(buf_next),
                                        buf_next))
        new_states = {"prelude": tuple(new_prelude), "pattern": tuple(new_pattern)}
        emit = ((out, recurrent_state(new_states)) if capture_states
                else out)
        return (buf_next, new_states), emit

    return diag_step


def run_diagonal(layout: StackLayout, params: Dict, state0: Dict,
                 segments: jax.Array, apply_block: ApplyBlock,
                 *, remat: bool = False, buf_spec=None, grouped_apply=None,
                 capture_states: bool = False, band_skip=None,
                 stream_ys: bool = False, retain_pos: int = -1):
    """segments: [S, B, T, D] -> (ys [S, B, T, D], final_state).

    Same params/state structure as run_sequential — the two executors are
    interchangeable (that is the point of the paper: pure reordering).

    buf_spec: optional PartitionSpec for the slot buffer [L, B, T, D]. With
    the slot dim sharded over a mesh axis ('stage'), diagonal batching
    *becomes pipeline parallelism*: every stage applies its own layers with
    fully local weights and the shift lowers to one collective-permute per
    step — no per-layer tensor-parallel all-reduces (EXPERIMENTS.md §Perf).

    grouped_apply: optional fused grouped-block application
    ``(btype, stacked_params [n_super, ...], x [n_super, B, T, D],
    stacked_state) -> (y, new_state)`` replacing the default
    ``jax.vmap(apply_block)`` over each pattern position — the fast mode
    built by ``models.grouped_blocks.make_grouped_apply`` that launches the
    Pallas grouped kernels (grouped GEMM / batched flash attention / fused
    ARMT memory) over the whole group (EXPERIMENTS.md §Perf).

    capture_states: also return the per-step recurrent state (A/z/h/conv)
    of every layer as a third output with leading axis [S+L-1] — the raw
    material for segment-boundary snapshots (boundary_states_from_capture,
    serve/state_store.py). Constant-size per step, so the extra scan output
    is (S+L-1) x the recurrent-state footprint, not activations.

    band_skip: skip the fill/drain padding compute by running the schedule
    in valid-band phases (``_band_phases``) instead of one full-width scan.
    None (default) enables it exactly for the fused grouped path on
    single-position patterns without prelude/sharding — the configuration
    where the per-step grouped launch pays for every padded slot. The vmap
    path stays on the full-width body (the untouched exactness/autodiff
    oracle); results are equal either way.

    stream_ys: bounded-memory mode (DESIGN.md §15) — never materialize the
    full ``ys [S, B, T, D]``. Returns ``({"win": [W, B, T, D],
    "brow": [S, B, D]}, final_state[, captured])`` instead: ``win`` is a
    rolling window of the last ``W = min(L, S)`` drained segments (drained
    segment ``s`` lives at ``win[s % W]``; O(L·B·T·D), flat in S) and
    ``brow`` holds each segment's retained row ``ys[s, :, retain_pos]`` —
    the only per-segment data the serving consumers need
    (``boundary_logits`` / ``last_logits`` read exactly one position).
    Retained outputs are bit-exact vs the full path: the step body is the
    same closure, and ``win``/``brow`` writes are pure slices of the same
    emitted tensor. Stream mode always runs the full-width body (no banded
    phases) and indexes ``segments`` directly with a clamped cursor instead
    of building the O(S) drain-padded copy.
    """
    S = segments.shape[0]
    L = layout.n_layers
    n_steps = S + L - 1
    if stream_ys:
        return _run_diagonal_stream(
            layout, params, state0, segments, apply_block, remat=remat,
            buf_spec=buf_spec, grouped_apply=grouped_apply,
            capture_states=capture_states, retain_pos=retain_pos)
    if band_skip is None:
        band_skip = (grouped_apply is not None and len(layout.pattern) == 1
                     and not layout.prelude and buf_spec is None and L > 1)
    if band_skip:
        assert len(layout.pattern) == 1 and not layout.prelude \
            and buf_spec is None and L > 1, "band_skip unsupported here"
        return _run_diagonal_banded(
            layout, params, state0, segments, apply_block, remat=remat,
            grouped_apply=grouped_apply, capture_states=capture_states)

    pad = jnp.zeros((L - 1,) + segments.shape[1:], segments.dtype)
    xs_seg = jnp.concatenate([segments, pad], axis=0) if L > 1 else segments

    body = _diag_body(layout, params, apply_block, S, buf_spec=buf_spec,
                      grouped_apply=grouped_apply,
                      capture_states=capture_states)
    step_fn = jax.checkpoint(body) if remat else body

    _constrain = _constrain_fn(buf_spec)
    _constrain_states = _constrain_states_fn(buf_spec)
    buf0 = _constrain(jnp.zeros((L,) + segments.shape[1:], segments.dtype))
    state0 = dict(state0,
                  pattern=_constrain_states(tuple(state0["pattern"])))
    (_, final_state), emitted = jax.lax.scan(
        step_fn, (buf0, state0), (xs_seg, jnp.arange(n_steps)))
    if capture_states:
        ys, captured = emitted
        return ys[L - 1:], final_state, captured
    return emitted[L - 1:], final_state


def _run_diagonal_banded(layout: StackLayout, params: Dict, state0: Dict,
                         segments: jax.Array, apply_block: ApplyBlock, *,
                         remat: bool, grouped_apply, capture_states: bool):
    """``run_diagonal`` as a sequence of valid-band phases: each phase is a
    ``lax.scan`` whose step applies only a pow2-bucketed band of slots
    around the valid diagonal (``_band_phases``), so the fill/drain padding
    cells are never computed — total cell-applies drop from (S+L-1)*L
    toward the sequential executor's S*L while keeping the grouped launch.
    Emissions (and captures) from all phases concatenate to exactly the
    [S+L-1] streams the one-shot scan produces."""
    S = segments.shape[0]
    L = layout.n_layers
    pad = jnp.zeros((L - 1,) + segments.shape[1:], segments.dtype)
    xs_seg = jnp.concatenate([segments, pad], axis=0)

    carry = (jnp.zeros((L,) + segments.shape[1:], segments.dtype), state0)
    ys_parts, cap_parts = [], []
    for (i0, n, Gb, mode) in _band_phases(S, L):
        body = _diag_body(layout, params, apply_block, S,
                          grouped_apply=grouped_apply,
                          capture_states=capture_states, band=(Gb, mode))
        step_fn = jax.checkpoint(body) if remat else body
        if n == 1:
            # every fill/drain phase (and the mid phase when S == L) is a
            # single step: call the body directly instead of a trip-count-1
            # lax.scan. The step index becomes a static constant (so the
            # band start folds at trace time) and XLA can fuse each phase's
            # buffer scatter into the next phase's slice — a while loop is
            # an optimization barrier and copies the carry both ways.
            carry, emitted = step_fn(carry, (xs_seg[i0], i0))
            emitted = jax.tree_util.tree_map(lambda a: a[None], emitted)
        else:
            carry, emitted = jax.lax.scan(
                step_fn, carry, (xs_seg[i0:i0 + n], jnp.arange(i0, i0 + n)))
        if capture_states:
            ys_parts.append(emitted[0])
            cap_parts.append(emitted[1])
        else:
            ys_parts.append(emitted)
    ys = jnp.concatenate(ys_parts, axis=0)
    final_state = carry[1]
    if capture_states:
        captured = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *cap_parts)
        return ys[L - 1:], final_state, captured
    return ys[L - 1:], final_state


def _run_diagonal_stream(layout: StackLayout, params: Dict, state0: Dict,
                         segments: jax.Array, apply_block: ApplyBlock, *,
                         remat: bool, buf_spec, grouped_apply,
                         capture_states: bool, retain_pos: int):
    """``run_diagonal(stream_ys=True)``: one full-width scan whose carry
    holds the O(L·B·T·D) rolling window instead of emitting full drained
    segments, and whose per-step emission is the [B, D] retained row — so
    the scan's stacked output is O(S·B·D), not O(S·B·T·D). The input is
    indexed with a clamped cursor (no drain-padded O(S) copy; the inserted
    value at overshoot steps is discarded by the validity select, exactly
    as in ``pipeline_step``)."""
    S = segments.shape[0]
    L = layout.n_layers
    n_steps = S + L - 1
    W = min(L, S)
    body = _diag_body(layout, params, apply_block, S, buf_spec=buf_spec,
                      grouped_apply=grouped_apply,
                      capture_states=capture_states)
    step_fn = jax.checkpoint(body) if remat else body

    _constrain = _constrain_fn(buf_spec)
    _constrain_states = _constrain_states_fn(buf_spec)
    buf0 = _constrain(jnp.zeros((L,) + segments.shape[1:], segments.dtype))
    state0 = dict(state0,
                  pattern=_constrain_states(tuple(state0["pattern"])))
    win0 = jnp.zeros((W,) + segments.shape[1:], segments.dtype)
    rows0 = jnp.zeros((S,) + segments.shape[1:2] + segments.shape[3:],
                      segments.dtype)

    def step(carry, i):
        buf, states, win, rows = carry
        seg_in = jax.lax.dynamic_index_in_dim(
            segments, jnp.minimum(i, S - 1), 0, keepdims=False)
        (buf, states), emit = step_fn((buf, states), (seg_in, i))
        out, cap_e = emit if capture_states else (emit, None)
        # segment i-(L-1) drained this step: rotate it into the window and
        # keep its retained row (fill steps write nothing — idx < 0). Both
        # land in the *carry* (guarded in-place updates, clamped index)
        # rather than a scan emission: an emitted stream would stack
        # [S+L-1] rows into a fresh buffer that only exists to be sliced —
        # an O(S·B·D) temp the carry-resident buffer avoids (the flatness
        # curve in BENCH_longctx.json is measured on this program).
        idx = i - (L - 1)
        ok = idx >= 0                      # idx < S always (i <= S+L-2)
        ci = jnp.maximum(idx, 0)
        wi = jax.lax.rem(ci, jnp.int32(W))
        cur = jax.lax.dynamic_index_in_dim(win, wi, 0, keepdims=False)
        win = jax.lax.dynamic_update_index_in_dim(
            win, jnp.where(ok, out.astype(win.dtype), cur), wi, 0)
        row = out[:, retain_pos]
        cur_row = jax.lax.dynamic_index_in_dim(rows, ci, 0, keepdims=False)
        rows = jax.lax.dynamic_update_index_in_dim(
            rows, jnp.where(ok, row.astype(rows.dtype), cur_row), ci, 0)
        return (buf, states, win, rows), cap_e

    (_, final_state, win, rows), captured = jax.lax.scan(
        step, (buf0, state0, win0, rows0), jnp.arange(n_steps))
    if capture_states:
        return {"win": win, "brow": rows}, final_state, captured
    return {"win": win, "brow": rows}, final_state


# ---------------------------------------------------------------------------
# Resumable pipeline (interleaved chunked prefill, DESIGN.md §11)
# ---------------------------------------------------------------------------

def pipeline_init(layout: StackLayout, state0: Dict, segments: jax.Array,
                  *, capture_states: bool = False, stream_ys: bool = False):
    """Build ``(xs, carry)`` for a resumable diagonal prefill over
    ``segments [S, B, T, D]``.

    The carry is everything a suspended pipeline needs to resume bit-exactly:

      * ``buf``   [L, B, T, D] — the slot buffer;
      * ``state`` — the per-layer executor state tree;
      * ``step``  — int32 group cursor (fill/drain position; see
        core.schedule.segments_completed / segments_entered);
      * ``ys``    [S, B, T, D] — per-segment outputs, written as each
        segment drains from slot L-1;
      * ``cap``   (only with capture_states) — the per-group recurrent-state
        capture, leading axis [S+L-1], same layout the one-shot executor
        emits (so ``boundary_states_from_capture`` applies unchanged).

    ``stream_ys`` (DESIGN.md §15) replaces the O(S·B·T·D) ``ys`` buffer
    with the bounded-memory pair

      * ``win``  [min(L, S), B, T, D] — rolling window of the most recent
        drained segments (segment ``s`` at ``win[s % W]``);
      * ``brow`` [S, B, D] — each drained segment's retained row at the
        ``retain_pos`` the stepper is called with (the segment-boundary
        position ``boundary_logits``/``last_logits`` read),

    so the per-admission activation footprint is flat in S. The cell math
    is the shared step body either way — retained outputs are bit-exact.

    ``xs`` is the drain-padded segment input [S+L-1, B, T, D]; it is
    read-only, passed alongside the carry on every ``pipeline_step`` call
    and never donated.
    """
    S = segments.shape[0]
    L = layout.n_layers
    pad = jnp.zeros((L - 1,) + segments.shape[1:], segments.dtype)
    xs = jnp.concatenate([segments, pad], axis=0) if L > 1 else segments
    carry = {
        "buf": jnp.zeros((L,) + segments.shape[1:], segments.dtype),
        "state": state0,
        "step": jnp.zeros((), jnp.int32),
    }
    if stream_ys:
        W = min(L, S)
        B, D = segments.shape[1], segments.shape[3]
        carry["win"] = jnp.zeros((W,) + segments.shape[1:], segments.dtype)
        carry["brow"] = jnp.zeros((S, B, D), segments.dtype)
    else:
        carry["ys"] = jnp.zeros_like(segments)
    if capture_states:
        n_steps = S + L - 1
        carry["cap"] = jax.tree_util.tree_map(
            lambda a: jnp.zeros((n_steps,) + a.shape, a.dtype),
            recurrent_state(state0))
    return xs, carry


def pipeline_step(layout: StackLayout, params: Dict, xs: jax.Array,
                  carry: Dict, apply_block: ApplyBlock, *, n_groups: int = 1,
                  buf_spec=None, grouped_apply=None, remat: bool = False,
                  retain_pos: int = -1) -> Dict:
    """Advance a suspended pipeline by ``n_groups`` anti-diagonal groups.

    Pure ``(params, xs, carry) -> carry`` — jit (and donate the carry) at
    the caller; serve/engine.py's ``prefill_step`` does. Uses the same step
    body as ``run_diagonal``, so interleaving pipeline calls with anything
    else cannot change the result. Groups past the end of the grid are
    masked no-ops: the validity mask freezes the executor state and no
    ``ys``/``cap`` slot is written, so overshooting the final group (the
    last fixed-size call of a grid whose S+L-1 is not a multiple of
    n_groups) is safe — compile count stays one program per (S, n_groups).

    ``remat`` wraps the shared step body in ``jax.checkpoint`` — the same
    rematerialization ``run_diagonal(remat=True)`` applies, so the serve
    stepper honors ``cfg.remat`` like the blocking path (checkpoint does
    not change forward values; the two drivers stay bit-identical).

    Streaming carries (``pipeline_init(stream_ys=True)``) are detected by
    structure: the drained segment rotates into ``carry['win']`` and its
    ``retain_pos`` row lands in ``carry['brow']`` instead of a full ``ys``
    write (DESIGN.md §15).
    """
    stream = "win" in carry
    S = carry["brow"].shape[0] if stream else carry["ys"].shape[0]
    L = layout.n_layers
    n_steps = S + L - 1
    capture = "cap" in carry
    body = _diag_body(layout, params, apply_block, S, buf_spec=buf_spec,
                      grouped_apply=grouped_apply, capture_states=capture)
    if remat:
        body = jax.checkpoint(body)
    _constrain_states = _constrain_states_fn(buf_spec)
    carry = dict(carry, state=dict(
        carry["state"],
        pattern=_constrain_states(tuple(carry["state"]["pattern"]))))

    def sub(c, _):
        i = c["step"]
        seg_in = jax.lax.dynamic_index_in_dim(
            xs, jnp.minimum(i, xs.shape[0] - 1), 0, keepdims=False)
        (buf, states), emit = body((c["buf"], c["state"]), (seg_in, i))
        out, cap_e = emit if capture else (emit, None)
        # segment i-(L-1) drained this group: write it into ys — or, in
        # stream mode, rotate it into the window and keep its retained row
        # (guarded — fill steps and overshoot steps write nothing)
        idx = i - (L - 1)
        ok = (idx >= 0) & (idx < S)
        ci = jnp.clip(idx, 0, S - 1)
        if stream:
            W = c["win"].shape[0]
            wi = jax.lax.rem(ci, jnp.int32(W))
            curw = jax.lax.dynamic_index_in_dim(c["win"], wi, 0,
                                                keepdims=False)
            win = jax.lax.dynamic_update_index_in_dim(
                c["win"], jnp.where(ok, out.astype(c["win"].dtype), curw),
                wi, 0)
            row = out[:, retain_pos]
            curb = jax.lax.dynamic_index_in_dim(c["brow"], ci, 0,
                                                keepdims=False)
            brow = jax.lax.dynamic_update_index_in_dim(
                c["brow"], jnp.where(ok, row.astype(c["brow"].dtype), curb),
                ci, 0)
            new = dict(c, buf=buf, state=states, step=i + 1, win=win,
                       brow=brow)
        else:
            cur = jax.lax.dynamic_index_in_dim(c["ys"], ci, 0, keepdims=False)
            ys = jax.lax.dynamic_update_index_in_dim(
                c["ys"], jnp.where(ok, out.astype(c["ys"].dtype), cur), ci, 0)
            new = dict(c, buf=buf, state=states, step=i + 1, ys=ys)
        if capture:
            si = jnp.minimum(i, n_steps - 1)
            sok = i < n_steps

            def wr(b, e):
                old = jax.lax.dynamic_index_in_dim(b, si, 0, keepdims=False)
                return jax.lax.dynamic_update_index_in_dim(
                    b, jnp.where(sok, e.astype(b.dtype), old), si, 0)

            new["cap"] = jax.tree_util.tree_map(wr, c["cap"], cap_e)
        return new, None

    carry, _ = jax.lax.scan(sub, carry, None, length=n_groups)
    return carry


def pipeline_step_pool(layout: StackLayout, params: Dict, xs_pool: jax.Array,
                       carry_pool: Dict, apply_block: ApplyBlock, *,
                       n_groups: int = 1, grouped_apply=None,
                       pool_spec=None, remat: bool = False,
                       retain_pos: int = -1) -> Dict:
    """Advance a *pool* of suspended pipelines by ``n_groups`` groups each
    (pooled concurrent admissions, DESIGN.md §12).

    ``xs_pool`` / ``carry_pool`` are ``pipeline_step``'s arguments with a
    leading pool axis [N, ...] — N same-shape (S, B, T, D) carries stacked
    leaf-wise, including N independent ``step`` cursors [N]. The pool rides
    one ``jax.vmap`` of the single-carry step, so each member runs the
    exact same math as its own ``pipeline_step`` call — bit-identical by
    construction, which is the pooled==blocking token-identity argument.
    Heterogeneous progress is safe for the same reason fixed-budget
    stepping is: a member whose cursor overshot its grid (or a pow2 pad
    entry parked at the end, ``pipeline_pool_pad``) executes masked no-ops.

    ``pool_spec``: optional pytree of shardings matching ``carry_pool``
    (parallel/sharding.pool_carry_specs) applied to the pooled tree outside
    the vmap — the per-member internal buf/state constraints are disabled
    (``buf_spec=None``) because raw PartitionSpecs do not compose with the
    vmapped rank.

    Pure ``(params, xs_pool, carry_pool) -> carry_pool`` — jit (and donate
    the carry pool) at the caller; serve/engine.py's ``pool_prefill_step``
    does."""
    def constrain(tree):
        if pool_spec is None:
            return tree
        return jax.tree_util.tree_map(
            lambda a, s: jax.lax.with_sharding_constraint(a, s),
            tree, pool_spec)

    def step_one(xs, carry):
        return pipeline_step(layout, params, xs, carry, apply_block,
                             n_groups=n_groups, buf_spec=None,
                             grouped_apply=grouped_apply, remat=remat,
                             retain_pos=retain_pos)

    return constrain(jax.vmap(step_one)(xs_pool, constrain(carry_pool)))


def pipeline_pool_pad(xs: jax.Array, carry: Dict, n_steps: int):
    """A no-op pool member shaped like ``(xs, carry)``: zero buffers with
    the group cursor parked at ``n_steps``, so every group it runs is a
    masked no-op (the same overshoot masking fixed-budget stepping relies
    on; zeroed inputs are safe because ``_diag_body`` already applies
    blocks to zeroed invalid slots). Every leaf is a FRESH array — pooled
    steppers donate their carries, so a pad entry must never alias a live
    member or another pad."""
    pad_carry = jax.tree_util.tree_map(jnp.zeros_like, carry)
    pad_carry["step"] = jnp.full((), n_steps, jnp.int32)
    return jnp.zeros_like(xs), pad_carry


def pipeline_finalize(layout: StackLayout, carry: Dict):
    """Unpack a *completed* pipeline carry (``carry['step'] >= S+L-1``):
    returns ``(ys [S, B, T, D], final_state, captured)`` — the same triple
    (captured None unless the carry was built with capture_states) the
    one-shot ``run_diagonal`` produces, with ``captured`` already
    re-gathered into per-boundary snapshots. A streaming carry
    (``pipeline_init(stream_ys=True)``) finalizes to
    ``({"win": ..., "brow": ...}, final_state, captured)`` — the same pair
    ``run_diagonal(stream_ys=True)`` returns."""
    stream = "win" in carry
    S = carry["brow"].shape[0] if stream else carry["ys"].shape[0]
    captured = None
    if "cap" in carry:
        captured = boundary_states_from_capture(layout, carry["cap"], S)
    if stream:
        return {"win": carry["win"], "brow": carry["brow"]}, \
            carry["state"], captured
    return carry["ys"], carry["state"], captured
