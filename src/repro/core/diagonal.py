"""Diagonal-batching executor (the paper's contribution, paper Alg. 1).

Carries a slot buffer ``buf[L, B, T, D]`` with the invariant *slot l holds the
segment currently entering layer l*. Each scan step executes one anti-diagonal:
every slot advances one layer via a single grouped application per pattern
position — either ``jax.vmap(apply_block)`` (the exactness oracle) or the
fused grouped-kernel path (``grouped_apply``, models/grouped_blocks.py), the
TPU analogue of the paper's CUTLASS GroupedGEMM + batched-attention launch —
then the buffer shifts down one slot.

S + L - 1 steps total (minimal, Lemma 3.1); recurrence is exact: per-layer
states are updated by the same functions in the same order as the sequential
executor, only grouped across slots.

Two drivers share one anti-diagonal step body (``_diag_body``):

  * ``run_diagonal`` — the one-shot executor: a single ``lax.scan`` over all
    S + L - 1 groups (training / blocking prefill).
  * ``pipeline_init`` / ``pipeline_step`` / ``pipeline_finalize`` — the
    *resumable* pipeline (DESIGN.md §11): the carry (slot buffer, executor
    state, group cursor, per-segment output buffer, optional recurrent-state
    capture) is explicit, and each ``pipeline_step`` call advances a bounded
    number of groups, so a long prefill can be suspended between calls —
    e.g. to let decode chunks run (serve/scheduler.py) — and resumed
    bit-exactly. Sharing the step body is what makes the two drivers
    token-identical by construction. ``pipeline_step_pool`` batches N such
    carries (with independent cursors) into one launch for the scheduler's
    pooled concurrent admissions (DESIGN.md §12).
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.memory import recurrent_state
from repro.core.schedule import StackLayout

ApplyBlock = Callable[[str, Any, jax.Array, Any], tuple]


def _mask_state(valid, new, old):
    """Keep old state where the slot was invalid (pipeline fill/drain)."""
    def sel(n, o):
        v = valid.reshape(valid.shape + (1,) * (n.ndim - valid.ndim))
        return jnp.where(v, n, o)
    return jax.tree_util.tree_map(sel, new, old)


def boundary_states_from_capture(layout: StackLayout, captured: Dict,
                                 n_segments: int) -> Dict:
    """Assemble per-segment-boundary recurrent states from a diagonal run's
    per-step capture (run_diagonal(capture_states=True)).

    In the diagonal schedule, layer at slot l processes segment s at step
    s + l — so the state "prefix through segment c" is spread across steps:
    layer l's piece of boundary c was emitted at step (c-1) + l. This gathers
    those pieces into a tree whose leaves lead with a boundary axis [S, ...]
    (boundary c at index c-1), entirely device-side: one gather per leaf, no
    host transfer until the caller moves a snapshot off-device.
    """
    S = n_segments
    steps = jnp.arange(S)
    prelude = tuple(
        jax.tree_util.tree_map(lambda a, _j=j: a[steps + _j], captured["prelude"][j])
        for j in range(len(layout.prelude)))
    pattern = []
    for p in range(len(layout.pattern)):
        slots = jnp.asarray(layout.position_slots(p))            # [n_super]
        idx = steps[:, None] + slots[None, :]                    # [S, n_super]
        sup = jnp.arange(len(slots))[None, :]
        pattern.append(jax.tree_util.tree_map(
            lambda a: a[idx, sup], captured["pattern"][p]))
    return {"prelude": prelude, "pattern": tuple(pattern)}


def _spec_axes(buf_spec):
    slot_axis = buf_spec[0] if buf_spec is not None else None
    batch_axis = (buf_spec[1] if buf_spec is not None and len(buf_spec) > 1
                  else None)
    return slot_axis, batch_axis


def _constrain_fn(buf_spec):
    def _constrain(b):
        if buf_spec is not None:
            return jax.lax.with_sharding_constraint(b, buf_spec)
        return b
    return _constrain


def _constrain_states_fn(buf_spec):
    """Pin per-layer recurrent state (A/z/h/conv) to the slot sharding —
    otherwise GSPMD re-gathers the stage-sharded activations every step.
    State layout is [n_super, B, ...]: slot axis on dim 0, the buffer's
    batch axis on dim 1."""
    slot_axis, batch_axis = _spec_axes(buf_spec)

    def _constrain_states(pattern_states):
        if slot_axis is None:
            return pattern_states
        from jax.sharding import PartitionSpec as PS

        def one(leaf):
            rest = [None] * (leaf.ndim - 1)
            if leaf.ndim >= 2 and batch_axis is not None:
                rest[0] = batch_axis
            return jax.lax.with_sharding_constraint(
                leaf, PS(slot_axis, *rest))
        return tuple(jax.tree_util.tree_map(one, st) for st in pattern_states)

    return _constrain_states


def _diag_body(layout: StackLayout, params: Dict, apply_block: ApplyBlock,
               n_segments: int, *, buf_spec=None, grouped_apply=None,
               capture_states: bool = False):
    """One anti-diagonal group as a pure step function

        body((buf, states), (seg_in, i)) -> ((buf_next, states_next), emit)

    shared — the same closure, hence the same math in the same order — by
    the one-shot scan executor (run_diagonal) and the resumable pipeline
    stepper (pipeline_step). ``emit`` is the drained slot's output (plus
    the per-step recurrent-state capture when capture_states). Groups with
    ``i`` outside [0, S+L-2] are masked no-ops on the executor state: every
    slot is invalid, so states freeze and only the (ignored) buffer churns.
    """
    S = n_segments
    L = layout.n_layers
    P = len(layout.pattern)
    slot_ids = jnp.arange(L)
    pos_slots = [np.asarray(layout.position_slots(p)) for p in range(P)]
    _constrain = _constrain_fn(buf_spec)
    _constrain_states = _constrain_states_fn(buf_spec)

    def diag_step(carry, xs):
        # named_scope: the anti-diagonal group shows up as one labeled
        # region in XLA profiles, matching the serve stack's host spans
        # (DESIGN.md §13)
        with jax.named_scope("diag.antidiagonal"):
            return _diag_step(carry, xs)

    def _diag_step(carry, xs):
        buf, states = carry
        seg_in, i = xs
        # insert the new segment into slot 0 with an elementwise select (an
        # indexed write would re-layout the stage-sharded slot dim — the
        # select is local on every shard; seg_in is replicated over 'stage')
        is0 = (slot_ids == 0)[(...,) + (None,) * (buf.ndim - 1)]
        buf = _constrain(jnp.where(is0, seg_in[None].astype(buf.dtype), buf))
        # slot l holds segment i - l; valid iff 0 <= i - l < S. Clear invalid
        # fill/drain slots with a select, NOT a multiply: an inf/NaN produced
        # by a block applied to empty padding would survive `0 * inf = nan`
        # and poison any group-coupled application (grouped kernels, global
        # MoE dispatch) on the next step.
        valid = (i >= slot_ids) & (i - slot_ids < S)                     # [L]
        valid_b = valid[(...,) + (None,) * (buf.ndim - 1)]
        buf = jnp.where(valid_b, buf, jnp.zeros_like(buf))

        y = jnp.zeros_like(buf)
        new_prelude = []
        for j, t in enumerate(layout.prelude):
            yj, stj = apply_block(t, params["prelude"][j], buf[j],
                                  states["prelude"][j])
            y = y.at[j].set(yj)
            new_prelude.append(_mask_state(valid[j], stj, states["prelude"][j]))

        new_pattern = []
        for p, t in enumerate(layout.pattern):
            slots = pos_slots[p]
            contiguous = P == 1          # slots are base..base+n_super-1
            if contiguous:
                # plain slice: SPMD-transparent (a fancy-indexed gather would
                # all-gather the stage-sharded buffer every step)
                xp = jax.lax.slice_in_dim(buf, int(slots[0]),
                                          int(slots[0]) + len(slots), axis=0)
            else:
                xp = buf[slots]                               # [n_super, B, T, D]
            if grouped_apply is not None:
                yp, stp = grouped_apply(t, params["pattern"][p], xp,
                                        states["pattern"][p])
            else:
                grouped = jax.vmap(
                    lambda pp, xx, ss, _t=t: apply_block(_t, pp, xx, ss))
                yp, stp = grouped(params["pattern"][p], xp,
                                  states["pattern"][p])
            if contiguous:
                y = jax.lax.dynamic_update_slice_in_dim(
                    y, yp.astype(y.dtype), int(slots[0]), axis=0)
            else:
                y = y.at[slots].set(yp)
            new_pattern.append(
                _mask_state(valid[slots], stp, states["pattern"][p]))
        new_pattern = _constrain_states(tuple(new_pattern))

        out = y[L - 1]                      # segment i-(L-1) finished all layers
        y = _constrain(y)
        # shift as a roll: on a stage-sharded slot dim this lowers to ONE
        # boundary collective-permute instead of an all-gather of the buffer
        buf_next = jnp.roll(y, shift=1, axis=0)
        is0 = (slot_ids == 0)[(...,) + (None,) * (y.ndim - 1)]
        buf_next = _constrain(jnp.where(is0, jnp.zeros_like(buf_next),
                                        buf_next))
        new_states = {"prelude": tuple(new_prelude), "pattern": tuple(new_pattern)}
        emit = ((out, recurrent_state(new_states)) if capture_states
                else out)
        return (buf_next, new_states), emit

    return diag_step


def run_diagonal(layout: StackLayout, params: Dict, state0: Dict,
                 segments: jax.Array, apply_block: ApplyBlock,
                 *, remat: bool = False, buf_spec=None, grouped_apply=None,
                 capture_states: bool = False):
    """segments: [S, B, T, D] -> (ys [S, B, T, D], final_state).

    Same params/state structure as run_sequential — the two executors are
    interchangeable (that is the point of the paper: pure reordering).

    buf_spec: optional PartitionSpec for the slot buffer [L, B, T, D]. With
    the slot dim sharded over a mesh axis ('stage'), diagonal batching
    *becomes pipeline parallelism*: every stage applies its own layers with
    fully local weights and the shift lowers to one collective-permute per
    step — no per-layer tensor-parallel all-reduces (EXPERIMENTS.md §Perf).

    grouped_apply: optional fused grouped-block application
    ``(btype, stacked_params [n_super, ...], x [n_super, B, T, D],
    stacked_state) -> (y, new_state)`` replacing the default
    ``jax.vmap(apply_block)`` over each pattern position — the fast mode
    built by ``models.grouped_blocks.make_grouped_apply`` that launches the
    Pallas grouped kernels (grouped GEMM / batched flash attention / fused
    ARMT memory) over the whole group (EXPERIMENTS.md §Perf).

    capture_states: also return the per-step recurrent state (A/z/h/conv)
    of every layer as a third output with leading axis [S+L-1] — the raw
    material for segment-boundary snapshots (boundary_states_from_capture,
    serve/state_store.py). Constant-size per step, so the extra scan output
    is (S+L-1) x the recurrent-state footprint, not activations.
    """
    S = segments.shape[0]
    L = layout.n_layers
    n_steps = S + L - 1

    pad = jnp.zeros((L - 1,) + segments.shape[1:], segments.dtype)
    xs_seg = jnp.concatenate([segments, pad], axis=0) if L > 1 else segments

    body = _diag_body(layout, params, apply_block, S, buf_spec=buf_spec,
                      grouped_apply=grouped_apply,
                      capture_states=capture_states)
    step_fn = jax.checkpoint(body) if remat else body

    _constrain = _constrain_fn(buf_spec)
    _constrain_states = _constrain_states_fn(buf_spec)
    buf0 = _constrain(jnp.zeros((L,) + segments.shape[1:], segments.dtype))
    state0 = dict(state0,
                  pattern=_constrain_states(tuple(state0["pattern"])))
    (_, final_state), emitted = jax.lax.scan(
        step_fn, (buf0, state0), (xs_seg, jnp.arange(n_steps)))
    if capture_states:
        ys, captured = emitted
        return ys[L - 1:], final_state, captured
    return emitted[L - 1:], final_state


# ---------------------------------------------------------------------------
# Resumable pipeline (interleaved chunked prefill, DESIGN.md §11)
# ---------------------------------------------------------------------------

def pipeline_init(layout: StackLayout, state0: Dict, segments: jax.Array,
                  *, capture_states: bool = False):
    """Build ``(xs, carry)`` for a resumable diagonal prefill over
    ``segments [S, B, T, D]``.

    The carry is everything a suspended pipeline needs to resume bit-exactly:

      * ``buf``   [L, B, T, D] — the slot buffer;
      * ``state`` — the per-layer executor state tree;
      * ``step``  — int32 group cursor (fill/drain position; see
        core.schedule.segments_completed / segments_entered);
      * ``ys``    [S, B, T, D] — per-segment outputs, written as each
        segment drains from slot L-1;
      * ``cap``   (only with capture_states) — the per-group recurrent-state
        capture, leading axis [S+L-1], same layout the one-shot executor
        emits (so ``boundary_states_from_capture`` applies unchanged).

    ``xs`` is the drain-padded segment input [S+L-1, B, T, D]; it is
    read-only, passed alongside the carry on every ``pipeline_step`` call
    and never donated.
    """
    S = segments.shape[0]
    L = layout.n_layers
    pad = jnp.zeros((L - 1,) + segments.shape[1:], segments.dtype)
    xs = jnp.concatenate([segments, pad], axis=0) if L > 1 else segments
    carry = {
        "buf": jnp.zeros((L,) + segments.shape[1:], segments.dtype),
        "state": state0,
        "step": jnp.zeros((), jnp.int32),
        "ys": jnp.zeros_like(segments),
    }
    if capture_states:
        n_steps = S + L - 1
        carry["cap"] = jax.tree_util.tree_map(
            lambda a: jnp.zeros((n_steps,) + a.shape, a.dtype),
            recurrent_state(state0))
    return xs, carry


def pipeline_step(layout: StackLayout, params: Dict, xs: jax.Array,
                  carry: Dict, apply_block: ApplyBlock, *, n_groups: int = 1,
                  buf_spec=None, grouped_apply=None) -> Dict:
    """Advance a suspended pipeline by ``n_groups`` anti-diagonal groups.

    Pure ``(params, xs, carry) -> carry`` — jit (and donate the carry) at
    the caller; serve/engine.py's ``prefill_step`` does. Uses the same step
    body as ``run_diagonal``, so interleaving pipeline calls with anything
    else cannot change the result. Groups past the end of the grid are
    masked no-ops: the validity mask freezes the executor state and no
    ``ys``/``cap`` slot is written, so overshooting the final group (the
    last fixed-size call of a grid whose S+L-1 is not a multiple of
    n_groups) is safe — compile count stays one program per (S, n_groups).
    """
    S = carry["ys"].shape[0]
    L = layout.n_layers
    n_steps = S + L - 1
    capture = "cap" in carry
    body = _diag_body(layout, params, apply_block, S, buf_spec=buf_spec,
                      grouped_apply=grouped_apply, capture_states=capture)
    _constrain_states = _constrain_states_fn(buf_spec)
    carry = dict(carry, state=dict(
        carry["state"],
        pattern=_constrain_states(tuple(carry["state"]["pattern"]))))

    def sub(c, _):
        i = c["step"]
        seg_in = jax.lax.dynamic_index_in_dim(
            xs, jnp.minimum(i, xs.shape[0] - 1), 0, keepdims=False)
        (buf, states), emit = body((c["buf"], c["state"]), (seg_in, i))
        out, cap_e = emit if capture else (emit, None)
        # segment i-(L-1) drained this group: write it into ys (guarded —
        # fill steps and overshoot steps write nothing)
        idx = i - (L - 1)
        ok = (idx >= 0) & (idx < S)
        ci = jnp.clip(idx, 0, S - 1)
        cur = jax.lax.dynamic_index_in_dim(c["ys"], ci, 0, keepdims=False)
        ys = jax.lax.dynamic_update_index_in_dim(
            c["ys"], jnp.where(ok, out.astype(c["ys"].dtype), cur), ci, 0)
        new = dict(c, buf=buf, state=states, step=i + 1, ys=ys)
        if capture:
            si = jnp.minimum(i, n_steps - 1)
            sok = i < n_steps

            def wr(b, e):
                old = jax.lax.dynamic_index_in_dim(b, si, 0, keepdims=False)
                return jax.lax.dynamic_update_index_in_dim(
                    b, jnp.where(sok, e.astype(b.dtype), old), si, 0)

            new["cap"] = jax.tree_util.tree_map(wr, c["cap"], cap_e)
        return new, None

    carry, _ = jax.lax.scan(sub, carry, None, length=n_groups)
    return carry


def pipeline_step_pool(layout: StackLayout, params: Dict, xs_pool: jax.Array,
                       carry_pool: Dict, apply_block: ApplyBlock, *,
                       n_groups: int = 1, grouped_apply=None,
                       pool_spec=None) -> Dict:
    """Advance a *pool* of suspended pipelines by ``n_groups`` groups each
    (pooled concurrent admissions, DESIGN.md §12).

    ``xs_pool`` / ``carry_pool`` are ``pipeline_step``'s arguments with a
    leading pool axis [N, ...] — N same-shape (S, B, T, D) carries stacked
    leaf-wise, including N independent ``step`` cursors [N]. The pool rides
    one ``jax.vmap`` of the single-carry step, so each member runs the
    exact same math as its own ``pipeline_step`` call — bit-identical by
    construction, which is the pooled==blocking token-identity argument.
    Heterogeneous progress is safe for the same reason fixed-budget
    stepping is: a member whose cursor overshot its grid (or a pow2 pad
    entry parked at the end, ``pipeline_pool_pad``) executes masked no-ops.

    ``pool_spec``: optional pytree of shardings matching ``carry_pool``
    (parallel/sharding.pool_carry_specs) applied to the pooled tree outside
    the vmap — the per-member internal buf/state constraints are disabled
    (``buf_spec=None``) because raw PartitionSpecs do not compose with the
    vmapped rank.

    Pure ``(params, xs_pool, carry_pool) -> carry_pool`` — jit (and donate
    the carry pool) at the caller; serve/engine.py's ``pool_prefill_step``
    does."""
    def constrain(tree):
        if pool_spec is None:
            return tree
        return jax.tree_util.tree_map(
            lambda a, s: jax.lax.with_sharding_constraint(a, s),
            tree, pool_spec)

    def step_one(xs, carry):
        return pipeline_step(layout, params, xs, carry, apply_block,
                             n_groups=n_groups, buf_spec=None,
                             grouped_apply=grouped_apply)

    return constrain(jax.vmap(step_one)(xs_pool, constrain(carry_pool)))


def pipeline_pool_pad(xs: jax.Array, carry: Dict, n_steps: int):
    """A no-op pool member shaped like ``(xs, carry)``: zero buffers with
    the group cursor parked at ``n_steps``, so every group it runs is a
    masked no-op (the same overshoot masking fixed-budget stepping relies
    on; zeroed inputs are safe because ``_diag_body`` already applies
    blocks to zeroed invalid slots). Every leaf is a FRESH array — pooled
    steppers donate their carries, so a pad entry must never alias a live
    member or another pad."""
    pad_carry = jax.tree_util.tree_map(jnp.zeros_like, carry)
    pad_carry["step"] = jnp.full((), n_steps, jnp.int32)
    return jnp.zeros_like(xs), pad_carry


def pipeline_finalize(layout: StackLayout, carry: Dict):
    """Unpack a *completed* pipeline carry (``carry['step'] >= S+L-1``):
    returns ``(ys [S, B, T, D], final_state, captured)`` — the same triple
    (captured None unless the carry was built with capture_states) the
    one-shot ``run_diagonal`` produces, with ``captured`` already
    re-gathered into per-boundary snapshots."""
    S = carry["ys"].shape[0]
    captured = None
    if "cap" in carry:
        captured = boundary_states_from_capture(layout, carry["cap"], S)
    return carry["ys"], carry["state"], captured
