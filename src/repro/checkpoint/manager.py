"""Fault-tolerant checkpointing: atomic, keep-k, async, hash-verified,
elastic (resharding happens at restore via device_put with any mesh).

Layout:  <dir>/step_<n>/manifest.json + leaf_<i>.npy
Writes go to <dir>/.tmp_step_<n> then os.rename (atomic on POSIX), so a crash
mid-save never corrupts the latest checkpoint. ``restore`` verifies per-leaf
sha256 (truncated) recorded in the manifest.

Besides step-numbered training checkpoints there are *named blobs*
(``save_named``/``restore_named``): flat ``{key: ndarray}`` dicts stored
under <dir>/named/<digest>/ with the same atomic-rename + hash-verify
machinery. The serving state store (serve/state_store.py) uses these as its
disk-spill tier for evicted prefix snapshots and session states.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _hash(a: np.ndarray) -> str:
    return hashlib.sha256(a.tobytes()).hexdigest()[:16]


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, block: bool = False) -> None:
        # pull to host before handing to the writer thread
        leaves_p = jax.tree_util.tree_flatten_with_path(tree)[0]
        host = [(_path_str(p), np.asarray(jax.device_get(l)))
                for p, l in leaves_p]
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def _write(self, step: int, host) -> None:
        tmp = self.dir / f".tmp_step_{step}"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "leaves": []}
        for i, (name, arr) in enumerate(host):
            np.save(tmp / f"leaf_{i}.npy", arr)
            manifest["leaves"].append(
                {"i": i, "path": name, "shape": list(arr.shape),
                 "dtype": str(arr.dtype), "sha": _hash(arr)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None, *,
                shardings: Any = None, verify: bool = True) -> Any:
        """Restore into the structure of `like` (a pytree or shape tree).
        `shardings` (same structure) reshards onto any mesh — elastic
        restart on a different topology."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        arrays = []
        for leaf in manifest["leaves"]:
            arr = np.load(d / f"leaf_{leaf['i']}.npy")
            if verify and _hash(arr) != leaf["sha"]:
                raise IOError(f"checkpoint corruption at {leaf['path']}")
            arrays.append(arr)
        treedef = jax.tree_util.tree_structure(like)
        assert treedef.num_leaves == len(arrays), \
            f"checkpoint has {len(arrays)} leaves, expected {treedef.num_leaves}"
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree

    # ---------------------------------------------------------- named blobs
    def _named_dir(self, name: str) -> Path:
        digest = hashlib.sha256(name.encode()).hexdigest()[:24]
        return self.dir / "named" / digest

    def save_named(self, name: str, arrays) -> None:
        """Persist a flat {key: ndarray} dict under an arbitrary string name.
        Atomic (tmp dir + rename) and hash-verified like step checkpoints;
        synchronous — callers spill rarely (LRU eviction), not per step."""
        final = self._named_dir(name)
        tmp = final.parent / f".tmp_{final.name}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"name": name, "time": time.time(), "leaves": []}
        for i, (key, arr) in enumerate(arrays.items()):
            arr = np.asarray(arr)
            np.save(tmp / f"leaf_{i}.npy", arr)
            manifest["leaves"].append(
                {"i": i, "path": key, "shape": list(arr.shape),
                 "dtype": str(arr.dtype), "sha": _hash(arr)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        final.parent.mkdir(parents=True, exist_ok=True)
        os.rename(tmp, final)

    def has_named(self, name: str) -> bool:
        return (self._named_dir(name) / "manifest.json").exists()

    def restore_named(self, name: str, *, verify: bool = True):
        """Load a named blob back as a {key: ndarray} dict (insertion order
        = save order)."""
        d = self._named_dir(name)
        if not (d / "manifest.json").exists():
            raise FileNotFoundError(f"no named blob {name!r} in {self.dir}")
        manifest = json.loads((d / "manifest.json").read_text())
        out = {}
        for leaf in manifest["leaves"]:
            arr = np.load(d / f"leaf_{leaf['i']}.npy")
            if verify and _hash(arr) != leaf["sha"]:
                raise IOError(f"blob corruption at {name!r}/{leaf['path']}")
            out[leaf["path"]] = arr
        return out

    def delete_named(self, name: str) -> None:
        shutil.rmtree(self._named_dir(name), ignore_errors=True)
