"""Elementary layers: norms, RoPE, MLPs, embeddings. Pure functions on pytrees."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Norms (fp32 math, cast back)
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, p, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps) * p["w"].astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm(x: jax.Array, p, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


def norm(kind: str, x: jax.Array, p) -> jax.Array:
    return rmsnorm(x, p) if kind == "rmsnorm" else layernorm(x, p)


def norm_init(kind: str, d: int, dtype):
    if kind == "rmsnorm":
        return {"w": jnp.ones((d,), dtype)}
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------------------
# RoPE (llama convention: rotate-half over the leading `fraction` of head dims)
# ---------------------------------------------------------------------------

def rope_cos_sin(positions: jax.Array, d_rot: int, theta: float):
    """positions: [...,T] int -> cos,sin [...,T, d_rot//2] (fp32)."""
    inv = 1.0 / (theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot))
    ang = positions.astype(jnp.float32)[..., None] * inv       # [...,T,d_rot/2]
    return jnp.cos(ang), jnp.sin(ang)


@functools.lru_cache(maxsize=64)
def rope_cos_sin_cached(T: int, d_rot: int, theta: float):
    """Segment-local rope table (positions = arange(T)), computed eagerly
    once per (T, d_rot, theta) and cached. The returned arrays embed as
    on-device constants when closed over by a jit trace, so the diagonal
    executor's many single-step phase bodies share one table instead of
    re-deriving the trig per compiled step (loop-invariant-code-motion only
    rescues the multi-step mid phases; fill/drain bodies have no loop).
    Bitwise-identical to ``rope_cos_sin(jnp.arange(T)[None], ...)`` — same
    XLA elementwise chain, just run ahead of time (compile-time eval keeps
    it concrete even when first called under an active trace)."""
    with jax.ensure_compile_time_eval():
        return rope_cos_sin(jnp.arange(T)[None], d_rot, theta)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               fraction: float = 1.0) -> jax.Array:
    """x: [B, T, H, hd]; rotary applied to the first fraction*hd dims."""
    hd = x.shape[-1]
    d_rot = int(hd * fraction)
    d_rot -= d_rot % 2
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]                                      # [B?,T,1,d_rot/2]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(x: jax.Array, p) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, p["wg"])
    u = jnp.einsum("...d,df->...f", x, p["wu"])
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, p["wd"])


def mlp_gelu(x: jax.Array, p) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["wi"]) + p.get("bi", 0)
    h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("...f,fd->...d", h, p["wo"]) + p.get("bo", 0)


def ffn(act: str, x: jax.Array, p) -> jax.Array:
    return swiglu(x, p) if act == "silu" else mlp_gelu(x, p)


def ffn_init(key, act: str, d: int, f: int, dtype, bias: bool = False):
    s_in = d ** -0.5
    s_out = f ** -0.5
    if act == "silu":
        kg, ku, kd = jax.random.split(key, 3)
        return {"wg": (jax.random.normal(kg, (d, f)) * s_in).astype(dtype),
                "wu": (jax.random.normal(ku, (d, f)) * s_in).astype(dtype),
                "wd": (jax.random.normal(kd, (f, d)) * s_out).astype(dtype)}
    ki, ko = jax.random.split(key, 2)
    p = {"wi": (jax.random.normal(ki, (d, f)) * s_in).astype(dtype),
         "wo": (jax.random.normal(ko, (f, d)) * s_out).astype(dtype)}
    if bias:
        p["bi"] = jnp.zeros((f,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    s = (d_in ** -0.5) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out)) * s).astype(dtype)
