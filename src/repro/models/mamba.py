"""Mamba-1 block (selective SSM) with carried state — a PRMT member.

Layer-local recurrent state = (h [B, dI, dS], conv tail [B, d_conv-1, dI]);
carried across segments exactly like ARMT's (A, z), so the diagonal executor
schedules Mamba layers with no special casing.

Two scan strategies:
  * 'scan'  — token-sequential lax.scan (memory-light; the faithful mamba-1
              recurrence; the Pallas kernel fuses this in VMEM on TPU)
  * 'assoc' — chunked associative scan (log-depth within chunks; trades
              memory B*Q*dI*dS per chunk for parallelism)
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import SSMConfig
from repro.utils import cdiv


def mamba_dims(d_model: int, scfg: SSMConfig) -> Tuple[int, int]:
    d_inner = scfg.expand * d_model
    dt_rank = scfg.dt_rank or cdiv(d_model, 16)
    return d_inner, dt_rank


def mamba_param_init(key, d_model: int, scfg: SSMConfig, dtype) -> Dict:
    dI, dtr = mamba_dims(d_model, scfg)
    dS, dc = scfg.d_state, scfg.d_conv
    ks = jax.random.split(key, 6)
    s = d_model ** -0.5
    # S4D-real initialization for A
    A = jnp.tile(jnp.arange(1, dS + 1, dtype=jnp.float32)[None, :], (dI, 1))
    return {
        "in_proj": (jax.random.normal(ks[0], (d_model, 2 * dI)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, dI)) * dc ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((dI,), dtype),
        "x_proj": (jax.random.normal(ks[2], (dI, dtr + 2 * dS)) * dI ** -0.5).astype(dtype),
        "dt_proj": (jax.random.normal(ks[3], (dtr, dI)) * dtr ** -0.5).astype(dtype),
        "dt_bias": jnp.full((dI,), -4.6, dtype),   # softplus^-1(0.01)
        "A_log": jnp.log(A),                        # fp32
        "D": jnp.ones((dI,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (dI, d_model)) * dI ** -0.5).astype(dtype),
    }


def mamba_state_init(batch: int, d_model: int, scfg: SSMConfig, dtype) -> Dict:
    dI, _ = mamba_dims(d_model, scfg)
    return {
        "h": jnp.zeros((batch, dI, scfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, scfg.d_conv - 1, dI), dtype),
    }


def _causal_conv(xi: jax.Array, tail: jax.Array, w: jax.Array, b: jax.Array):
    """Depthwise causal conv1d. xi: [B,T,dI]; tail: [B,dc-1,dI] (prev inputs).
    Returns (y [B,T,dI], new_tail)."""
    dc = w.shape[0]
    T = xi.shape[1]
    xp = jnp.concatenate([tail.astype(xi.dtype), xi], axis=1)   # [B, T+dc-1, dI]
    y = sum(xp[:, j:j + T, :] * w[j] for j in range(dc)) + b
    new_tail = jax.lax.dynamic_slice_in_dim(xp, T, dc - 1, axis=1)
    return y, new_tail


def _ssm_inputs(xc: jax.Array, p: Dict, scfg: SSMConfig):
    """xc: [B,T,dI] (post-conv, post-silu) -> (dt [B,T,dI], Bt, Ct [B,T,dS])."""
    dS = scfg.d_state
    dtr = p["dt_proj"].shape[0]
    proj = jnp.einsum("bti,ir->btr", xc, p["x_proj"])
    dt_r, Bt, Ct = jnp.split(proj, [dtr, dtr + dS], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,ri->bti", dt_r, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    return dt, Bt.astype(jnp.float32), Ct.astype(jnp.float32)


def selective_scan(xc, dt, Bt, Ct, A_log, h0, *, method: str = "scan",
                   chunk: int = 128):
    """h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t
    xc: [B,T,dI]; dt: [B,T,dI]; Bt/Ct: [B,T,dS]; h0: [B,dI,dS] fp32.
    Returns (y [B,T,dI] fp32, h_T)."""
    A = -jnp.exp(A_log.astype(jnp.float32))                     # [dI,dS]
    x32 = xc.astype(jnp.float32)

    if method == "assoc":
        return _selective_scan_assoc(x32, dt, Bt, Ct, A, h0, chunk)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp                               # [B,dI],[B,dI],[B,dS]
        da = jnp.exp(dt_t[..., None] * A)                       # [B,dI,dS]
        h = da * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bis,bs->bi", h, C_t)
        return h, y

    inputs = (x32.swapaxes(0, 1), dt.swapaxes(0, 1),
              Bt.swapaxes(0, 1), Ct.swapaxes(0, 1))
    hT, ys = jax.lax.scan(step, h0, inputs)
    return ys.swapaxes(0, 1), hT


def _selective_scan_assoc(x32, dt, Bt, Ct, A, h0, chunk: int):
    """Chunked associative scan: within a chunk, combine (a,b) pairs with
    (a2*a1, a2*b1+b2); chunks processed sequentially with carried h."""
    B, T, dI = x32.shape
    dS = A.shape[1]
    nC = cdiv(T, chunk)
    pad = nC * chunk - T
    if pad:
        z = lambda u: jnp.pad(u, ((0, 0), (0, pad)) + ((0, 0),) * (u.ndim - 2))
        x32, dt, Bt, Ct = z(x32), z(dt), z(Bt), z(Ct)

    @jax.checkpoint
    def chunk_step(h, inp):
        # remat: backward recomputes the intra-chunk scan, so only the
        # chunk-boundary states h are saved — the memory-term fix for the
        # 64-layer SSM archs (EXPERIMENTS.md §Perf)
        xq, dtq, Bq, Cq = inp                                    # [B,Q,...]
        a = jnp.exp(dtq[..., None] * A)                          # [B,Q,dI,dS]
        b = (dtq * xq)[..., None] * Bq[:, :, None, :]            # [B,Q,dI,dS]

        def comb(l, r):
            return (r[0] * l[0], r[0] * l[1] + r[1])
        aa, bb = jax.lax.associative_scan(comb, (a, b), axis=1)
        hs = aa * h[:, None] + bb                                # [B,Q,dI,dS]
        y = jnp.einsum("bqis,bqs->bqi", hs, Cq)
        return hs[:, -1], y

    xs = tuple(u.reshape(B, nC, chunk, *u.shape[2:]).swapaxes(0, 1)
               for u in (x32, dt, Bt, Ct))
    hT, ys = jax.lax.scan(chunk_step, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, nC * chunk, dI)
    return y[:, :T], hT


def mamba_mixer(x, p, scfg: SSMConfig, state: Dict, *, method: str = "scan"):
    """Full mamba mixer over a segment. x: [B,T,D] -> (y [B,T,D], new_state)."""
    dI = p["in_proj"].shape[1] // 2
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])
    xi, z = xz[..., :dI], xz[..., dI:]
    xc, new_tail = _causal_conv(xi, state["conv"], p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    dt, Bt, Ct = _ssm_inputs(xc, p, scfg)
    y32, hT = selective_scan(xc, dt, Bt, Ct, p["A_log"], state["h"],
                             method=method)
    y32 = y32 + p["D"] * xc.astype(jnp.float32)
    y = (y32.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bti,id->btd", y, p["out_proj"])
    return out, {"h": hT, "conv": new_tail}


def mamba_decode_step(x, p, scfg: SSMConfig, state: Dict):
    """Single-token decode. x: [B,1,D] -> (y [B,1,D], new_state)."""
    return mamba_mixer(x, p, scfg, state, method="scan")
