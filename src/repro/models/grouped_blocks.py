"""Fused grouped-block execution for the diagonal executor (paper §3.3, §4.2).

Each diagonal step advances one pattern position's ``n_super`` stacked layers
simultaneously — the paper realizes that grouped launch as CUTLASS
GroupedGEMM for the stacked linear projections plus one batched attention
call over the whole group. The executor's default path expresses the group
as ``jax.vmap(apply_block)`` and leaves the lowering to XLA; this module is
the fast mode that executes the block with the grouped Pallas kernels
directly:

  * ``grouped_gemm``      — QKV / output / FFN projections, per-layer weights
                            stacked on the group dim, with a fused bias +
                            activation epilogue so the QKV bias add and the
                            FFN up-proj + activation stay in VMEM
  * ``segment_attention`` — one batched flash-attention launch over
                            ``N = n_super * B`` (the kernel's designed layout)
  * ``assoc_read/update`` — ARMT memory math (eqs. 3-6) with per-group
                            projection weights, fp32 state

Layout contract (EXPERIMENTS.md §Perf, DESIGN.md §7): the slot slice
``x [n_super, B, T, D]`` flattens to ``N = n_super * B`` rows; projections run
as ``[n_super, B*T, D]`` grouped GEMMs; attention and ARMT memory run over N.

Only ``attn`` blocks (pre-norm attention + dense FFN + optional ARMT memory)
have a fused implementation; every other block type falls back to the vmap
path inside the same closure, so heterogeneous patterns still work. The vmap
path (``grouped_impl="vmap"``) remains the CPU/exactness oracle — the fused
path must match it to fp32 tolerance (tests/test_grouped_blocks.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.attention import rope_qk
from repro.models.blocks import make_apply_block
from repro.models.layers import norm, rmsnorm


def resolve_grouped_apply(cfg, impl=None, *, mode: str = "segmented",
                          ssm_method: str = "assoc",
                          use_kernel: bool | None = None,
                          interpret: bool | None = None,
                          remat: bool = False):
    """Resolve the ``grouped_impl`` knob (explicit override, else
    cfg.grouped_impl) to the executor's grouped application: ``None`` for
    'vmap' (the executor falls back to ``jax.vmap(apply_block)``), a
    ``make_grouped_apply`` closure for 'fused'. Shared by
    ``models.model.forward_hidden`` and the serving prefill pipeline
    (``serve/engine.py``), so the blocking and resumable prefill paths
    select the exact same grouped launch.

    ``remat`` (cfg.remat mapped by the caller) wraps the fused cell in
    ``jax.checkpoint`` so the grouped path recomputes intra-cell
    activations on the backward pass like the vmap path does — forward
    values are unchanged (tests/test_remat_paths.py)."""
    impl = impl or cfg.grouped_impl
    if impl not in ("vmap", "fused"):
        raise ValueError(f"unknown grouped_impl {impl!r} "
                         "(expected 'vmap' or 'fused')")
    if impl == "vmap":
        return None
    return make_grouped_apply(cfg, mode=mode, ssm_method=ssm_method,
                              use_kernel=use_kernel, interpret=interpret,
                              remat=remat)


def make_grouped_apply(cfg, *, mode: str = "segmented",
                       ssm_method: str = "scan",
                       use_kernel: bool | None = None,
                       interpret: bool | None = None,
                       remat: bool = False):
    """Returns grouped_apply(btype, stacked_params, x, stacked_state).

    Drop-in replacement for ``jax.vmap(apply_block)`` over one pattern
    position: ``stacked_params`` leaves are ``[n_super, ...]`` (as produced
    by ``init_params``), ``x`` is the slot slice ``[n_super, B, T, D]``,
    state leaves are ``[n_super, B, ...]``.

    use_kernel/interpret follow the kernels/ops.py convention: None picks the
    Pallas kernels on TPU and the jnp oracles elsewhere; tests pass
    ``use_kernel=True, interpret=True`` to exercise the kernel bodies on CPU.
    """
    base = make_apply_block(cfg, mode=mode, ssm_method=ssm_method)
    armt_on = cfg.armt is not None and mode == "segmented"
    M = cfg.armt.num_mem_tokens if armt_on else 0
    nu = cfg.armt.nu if armt_on else 3
    # cfg-level kernel_backend knob (configs/__init__.py) maps onto the
    # per-call overrides unless the caller set them explicitly — the
    # dispatch resolver (kernels/dispatch.py) sees one consistent decision
    # from forward_hidden and ServeEngine.exec_apply alike
    kb = getattr(cfg, "kernel_backend", "auto")
    if use_kernel is None and kb != "auto":
        use_kernel = kb != "xla"
        if interpret is None and kb == "pallas_interpret":
            interpret = True
    kw = dict(use_kernel=use_kernel, interpret=interpret)

    def fallback(t, p, x, st):
        return jax.vmap(lambda pp, xx, ss, _t=t: base(_t, pp, xx, ss))(p, x, st)

    def gg(h, w, bias=None, act=None):
        # h: [G, B, T, Din] @ w: [G, Din, Dout] as one grouped GEMM — the
        # 4-D layout goes through un-flattened (kops keeps it on the XLA
        # branch: the fast CPU lowering; the pallas branch flattens at the
        # kernel boundary)
        return kops.grouped_gemm(h, w, bias, activation=act, **kw)

    def snorm(h, p):
        # per-layer norm weights [G, D] broadcast against h [G, B, T, D];
        # reuses the fp32 norm math from models/layers.py unchanged
        return norm(cfg.norm, h, {k: v[:, None, None, :] for k, v in p.items()})

    cb = getattr(cfg, "cell_block", 0)

    def blockwise_ffn(h, p):
        # BPT-style query-blocked FFN on the grouped layout (DESIGN.md
        # §15): chunk the token axis of [G, B, T, D], run the full grouped
        # FFN (norm -> up/gate -> down) per chunk under jax.checkpoint, so
        # only one O(G * B * cell_block * d_ff) intermediate is live at a
        # time; lax.map keeps the chunks sequential. The pad tail is
        # dropped after the reshape.
        G, B, T, D = h.shape
        nb = -(-T // cb)
        hp = jnp.pad(h, ((0, 0), (0, 0), (0, nb * cb - T), (0, 0)))
        hb = jnp.moveaxis(hp.reshape(G, B, nb, cb, D), 2, 0)

        def one_block(blk):
            h2 = snorm(blk, p["ln2"])
            pf = p["ffn"]
            if cfg.act == "silu":
                return gg(gg(h2, pf["wg"], act="silu") * gg(h2, pf["wu"]),
                          pf["wd"])
            mid = gg(h2, pf["wi"], pf.get("bi"), act="gelu")
            return gg(mid, pf["wo"], pf.get("bo"))

        yb = jax.lax.map(jax.checkpoint(one_block), hb)
        return jnp.moveaxis(yb, 0, 2).reshape(G, B, nb * cb, D)[:, :, :T]

    def fused_attn(p, x, state):
        G, B, T, D = x.shape
        N = G * B
        hd, nq, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
        new_state = dict(state)
        if armt_on:
            A_f = state["A"].reshape((N,) + state["A"].shape[2:])
            z_f = state["z"].reshape((N,) + state["z"].shape[2:])
            read = kops.assoc_read(x.reshape(N, T, D), p["mem"]["wq"],
                                   A_f, z_f, nu=nu, **kw)
            x = x + read.reshape(G, B, T, -1)

        pa = p["attn"]
        hln = snorm(x, p["ln1"])
        q = gg(hln, pa["wq"], pa.get("bq")).reshape(G, B, T, nq, hd)
        k = gg(hln, pa["wk"], pa.get("bk")).reshape(G, B, T, nkv, hd)
        v = gg(hln, pa["wv"], pa.get("bv")).reshape(G, B, T, nkv, hd)
        if cfg.qk_norm:
            q = rmsnorm(q, {"w": pa["qn"]["w"][:, None, None, None, :]})
            k = rmsnorm(k, {"w": pa["kn"]["w"][:, None, None, None, :]})
        # cached_tables: the cos/sin constants are shared by every banded
        # phase body (bitwise-equal values, but the constant shifts XLA
        # fusion ulps — see rope_qk), so like the dispatched attention
        # lowerings it stays off the use_kernel=False exactness-oracle
        # path, which must compile the same program as the vmap reference
        q, k = rope_qk(q, k, cfg, cached_tables=use_kernel is not False)
        # stay in the 5-D [G,B,T,H,hd] layout: the XLA branch runs the
        # (g,b,h)-batched dot directly (the fast CPU lowering, identical
        # to what the vmap path produces) and only the pallas branch pays
        # the flatten/transpose at the kernel boundary
        o = kops.segment_attention(q, k, v, causal=True,
                                   window=cfg.sliding_window, **kw)
        o = o.reshape(G, B, T, nq * hd)
        h = x + gg(o, pa["wo"])

        # With B == 1 (the serving/admission layout) the ARMT update can
        # ride the last GEMM's epilogue: the memory tokens are the final M
        # rows of the flattened [G, B*T, D] output, so one
        # grouped_gemm_armt_update launch replaces down-proj + update (the
        # two separate per-anti-diagonal-cell launches). B > 1 interleaves
        # batch rows, so the fused epilogue cannot see per-batch tails —
        # fall back to the two-launch path there. The blockwise-FFN path
        # (cell_block) computes the FFN in token chunks, so the epilogue
        # never sees the whole tail either — also two-launch.
        blockwise = cb > 0 and T > cb and "ffn" in p
        fuse_update = armt_on and M > 0 and B == 1 and "ffn" in p \
            and not blockwise
        if blockwise:
            y = h + blockwise_ffn(h, p)
        elif "ffn" in p:
            h2 = snorm(h, p["ln2"])
            pf = p["ffn"]
            if cfg.act == "silu":       # swiglu: silu epilogue on the gate
                gate = gg(h2, pf["wg"], act="silu")
                up = gg(h2, pf["wu"])
                last_in, last_w, last_b = gate * up, pf["wd"], None
            else:                       # gelu MLP: bias + act epilogue
                mid = gg(h2, pf["wi"], pf.get("bi"), act="gelu")
                last_in, last_w, last_b = mid, pf["wo"], pf.get("bo")
            if fuse_update:
                y2, A2, z2 = kops.grouped_gemm_armt_update(
                    last_in, last_w, h, p["mem"]["wk"], p["mem"]["wv"],
                    p["mem"]["wb"], A_f, z_f, last_b, M=M, nu=nu, **kw)
                new_state["A"] = A2.reshape(state["A"].shape)
                new_state["z"] = z2.reshape(state["z"].shape)
                return y2, new_state
            y = h + gg(last_in, last_w, last_b)
        else:
            y = h

        if armt_on and M > 0:
            mtok = y[:, :, -M:, :].reshape(N, M, D)
            A2, z2 = kops.assoc_update(mtok, p["mem"]["wk"], p["mem"]["wv"],
                                       p["mem"]["wb"], A_f, z_f, nu=nu, **kw)
            new_state["A"] = A2.reshape(state["A"].shape)
            new_state["z"] = z2.reshape(state["z"].shape)
        return y, new_state

    # cfg.remat threading for the fused cell: checkpoint the whole grouped
    # cell so the backward pass recomputes intra-cell activations instead
    # of holding them — forward values are unchanged (the vmap path gets
    # the same guarantee from the executor-level checkpoint in
    # run_diagonal / pipeline_step)
    cell = jax.checkpoint(fused_attn) if remat else fused_attn

    def grouped_apply(t, p, x, state):
        if t == "attn":
            return cell(p, x, state)
        return fallback(t, p, x, state)

    return grouped_apply
