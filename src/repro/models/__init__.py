from repro.models.model import (init_params, param_specs, init_state,
                                forward_hidden, lm_loss, last_logits,
                                boundary_logits, embed_segments,
                                decode_state_init, decode_state_shapes,
                                decode_state_sharding, decode_step,
                                flush_segment, mask_decode_state, encode)
