"""Attention: GQA/MHA, sliding-window, cross-attention, KV caches.

The jnp path here is the reference the Pallas flash kernel (kernels/) is
validated against; the model can route the segment-attention hot spot through
the kernel via ``use_kernel`` (TPU) while CPU tests keep the jnp path.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import (apply_rope, rope_cos_sin,
                                 rope_cos_sin_cached, rmsnorm)

NEG_INF = -1e30


def attn_param_init(key, cfg, dtype, *, cross: bool = False) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, nq * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, nkv * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, nkv * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (nq * hd, d)) * (nq * hd) ** -0.5).astype(dtype),
    }
    if cfg.qkv_bias or cfg.norm == "layernorm":   # whisper/chatglm/qwen biases
        p["bq"] = jnp.zeros((nq * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    if cfg.qk_norm:
        p["qn"] = {"w": jnp.ones((hd,), dtype)}
        p["kn"] = {"w": jnp.ones((hd,), dtype)}
    return p


def _project_qkv(x, p, cfg):
    B, T, _ = x.shape
    hd = cfg.head_dim
    q = jnp.einsum("btd,de->bte", x, p["wq"]) + p.get("bq", 0)
    k = jnp.einsum("btd,de->bte", x, p["wk"]) + p.get("bk", 0)
    v = jnp.einsum("btd,de->bte", x, p["wv"]) + p.get("bv", 0)
    q = q.reshape(B, T, cfg.n_heads, hd)
    k = k.reshape(B, T, cfg.n_kv_heads, hd)
    v = v.reshape(B, T, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["qn"])
        k = rmsnorm(k, p["kn"])
    return q, k, v


def rope_qk(q, k, cfg, positions=None, *, cached_tables: bool = False):
    """Apply RoPE to q/k [..., T, H, hd] from one shared cos/sin table.
    Used by both the reference attention path and the fused grouped-block
    path (models/grouped_blocks.py) so the rotary math is bit-identical.

    cached_tables: with segment-local positions, take the cos/sin table
    from the eager per-shape cache (rope_cos_sin_cached) so it embeds as
    one on-device constant shared by every compiled step body — what the
    banded diagonal driver's single-step phase programs need. The values
    are bitwise-identical, but a constant table changes XLA's fusion
    choices, which perturbs ulps elsewhere in the program — so the flag
    stays off on the reference/training paths to keep their compiled
    programs exactly as before (the fused path re-verifies equivalence
    against them at fp32 tolerance, tests/test_grouped_blocks.py)."""
    if not cfg.use_rope:
        return q, k
    d_rot = int(cfg.head_dim * cfg.rope_fraction)
    if positions is None and cached_tables:
        cos, sin = rope_cos_sin_cached(q.shape[-3], d_rot - d_rot % 2,
                                       cfg.rope_theta)
    else:
        if positions is None:
            positions = jnp.arange(q.shape[-3])[None]
        cos, sin = rope_cos_sin(positions, d_rot - d_rot % 2, cfg.rope_theta)
    return (apply_rope(q, cos, sin, cfg.rope_fraction),
            apply_rope(k, cos, sin, cfg.rope_fraction))


def sdpa(q, k, v, mask=None) -> jax.Array:
    """q: [B,T,Hq,hd], k/v: [B,S,Hkv,hd] (GQA expanded by repeat), fp32 softmax."""
    B, T, Hq, hd = q.shape
    Hkv = k.shape[2]
    if Hq != Hkv:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = hd ** -0.5
    logits = jnp.einsum("bthd,bshd->bhts", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhts,bshd->bthd", w, v)


def sdpa_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                 block: int = 512) -> jax.Array:
    """Flash-style attention in pure jnp: scan over key blocks with an
    online softmax — no [T, S] score tensor is ever materialized (the HLO
    mirror of kernels/flash_attention.py; used by the roofline cells)."""
    B, T, Hq, hd = q.shape
    S = k.shape[1]
    Hkv = k.shape[2]
    if Hq != Hkv:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    block = min(block, S)
    n_blk = (S + block - 1) // block
    pad = n_blk * block - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = hd ** -0.5
    q32 = q.astype(jnp.float32) * scale
    kb = k.reshape(B, n_blk, block, Hq, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, n_blk, block, Hq, hd).transpose(1, 0, 3, 2, 4)
    qpos = jnp.arange(T)[:, None]

    def step(carry, inp):
        m_i, l_i, acc = carry
        kc, vc, ib = inp                      # [B,H,block,hd] x2, scalar
        s = jnp.einsum("bthd,bhsd->bhts", q32, kc.astype(jnp.float32))
        kpos = (ib * block + jnp.arange(block))[None, :]
        mask = kpos < S
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > (qpos - window)
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m_i, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhts,bhsd->bhtd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hq, T), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hq, T), jnp.float32)
    a0 = jnp.zeros((B, Hq, T, hd), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                      (kb, vb, jnp.arange(n_blk)))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]      # [B,H,T,hd]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def causal_mask(T: int, S: int, *, offset: int = 0,
                window: int = 0) -> jax.Array:
    """[1,1,T,S] boolean; query t attends key s iff s <= t+offset
    (and within sliding window if window>0)."""
    qpos = jnp.arange(T)[:, None] + offset
    kpos = jnp.arange(S)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > (qpos - window)
    return m[None, None]


def attention(x, p, cfg, *, positions=None, mask=None, bidirectional=False):
    """Self-attention over x [B,T,D] (full segment/sequence, no cache)."""
    B, T, _ = x.shape
    q, k, v = _project_qkv(x, p, cfg)
    q, k = rope_qk(q, k, cfg, positions)
    impl = getattr(cfg, "attn_impl", "dense")
    if impl == "chunked":
        o = sdpa_chunked(q, k, v, causal=not bidirectional,
                         window=cfg.sliding_window)
    elif impl == "pallas":
        # the TPU flash kernel (kernels/flash_attention.py); interpret mode
        # executes the kernel body on CPU for validation
        from repro.kernels import ops as kops
        o = kops.segment_attention(
            q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
            causal=not bidirectional, window=cfg.sliding_window,
            use_kernel=True, interpret=not kops.on_tpu()).swapaxes(1, 2)
    else:
        if mask is None and not bidirectional:
            mask = causal_mask(T, T, window=cfg.sliding_window)
        o = sdpa(q, k, v, mask)
    o = o.reshape(B, T, cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bte,ed->btd", o, p["wo"])


def cross_attention(x, p, ck, cv, cfg):
    """x: [B,T,D]; ck/cv: precomputed encoder K/V [B,F,Hkv,hd]."""
    B, T, _ = x.shape
    q = jnp.einsum("btd,de->bte", x, p["wq"]) + p.get("bq", 0)
    q = q.reshape(B, T, cfg.n_heads, cfg.head_dim)
    o = sdpa(q, ck, cv, None)
    o = o.reshape(B, T, cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bte,ed->btd", o, p["wo"])


def cross_kv(enc_out, p, cfg):
    """Precompute cross-attention K/V from encoder output [B,F,D]."""
    B, F, _ = enc_out.shape
    k = (jnp.einsum("bfd,de->bfe", enc_out, p["wk"]) + p.get("bk", 0))
    v = (jnp.einsum("bfd,de->bfe", enc_out, p["wv"]) + p.get("bv", 0))
    return (k.reshape(B, F, cfg.n_kv_heads, cfg.head_dim),
            v.reshape(B, F, cfg.n_kv_heads, cfg.head_dim))


# ---------------------------------------------------------------------------
# Decode (single token against a cache)
# ---------------------------------------------------------------------------

def kv_cache_init(batch: int, max_len: int, cfg, dtype) -> Dict:
    hd = cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
    }


def decode_attention(x, p, cfg, cache: Dict, pos: jax.Array):
    """Decode step for Tq >= 1 queries (Tq=1: autoregressive decode; Tq>1:
    chunked prefill / ARMT memory-token flush). x: [B,Tq,D]; pos: scalar
    int32 = number of tokens already in the cache, or int32 [B] vector of
    per-row positions (continuous-batching slots at heterogeneous phases).
    Returns (out, new_cache)."""
    B, Tq, _ = x.shape
    q, k, v = _project_qkv(x, p, cfg)
    per_slot = getattr(pos, "ndim", 0) == 1
    if per_slot:
        positions = pos[:, None] + jnp.arange(Tq)[None, :]         # [B,Tq]
        q, k = rope_qk(q, k, cfg, positions)
        upd = jax.vmap(lambda c, u, s: jax.lax.dynamic_update_slice_in_dim(
            c, u, s, axis=0))
        ck, cv = upd(cache["k"], k, pos), upd(cache["v"], v, pos)
        qpos = positions[:, :, None]                               # [B,Tq,1]
        kpos = jnp.arange(ck.shape[1])[None, None, :]              # [1,1,S]
        mask = kpos <= qpos
        if cfg.sliding_window > 0:
            mask &= kpos > (qpos - cfg.sliding_window)
        mask = mask[:, None]                                       # [B,1,Tq,S]
    else:
        q, k = rope_qk(q, k, cfg, (pos + jnp.arange(Tq))[None])
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1)
        S = ck.shape[1]
        kpos = jnp.arange(S)[None, :]                              # [1,S]
        qpos = (pos + jnp.arange(Tq))[:, None]                     # [Tq,1]
        mask = kpos <= qpos
        if cfg.sliding_window > 0:
            mask &= kpos > (qpos - cfg.sliding_window)
        mask = mask[None, None]
    if getattr(cfg, "attn_impl", "dense") == "pallas" and Tq == 1:
        # single-token serve hot path: the dedicated decode kernel
        # (kernels/decode_attention.py) reads only the valid cache prefix
        from repro.kernels import ops as kops
        lens = (pos if per_slot else jnp.full((B,), pos, jnp.int32)) + 1
        o = kops.decode_attention(q[:, 0], ck, cv, lens,
                                  window=cfg.sliding_window,
                                  use_kernel=True,
                                  interpret=not kops.on_tpu())[:, None]
    else:
        o = sdpa(q, ck, cv, mask)
    o = o.reshape(B, Tq, cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bte,ed->btd", o, p["wo"]), {"k": ck, "v": cv}
