"""Mixture-of-Experts FFN with argsort-based fixed-capacity dispatch.

Scales to hundreds of experts (Kimi-K2: 384) where the classic [T, E, C]
one-hot dispatch einsum would need terabytes: tokens are routed by sorting
(token, k) pairs by expert id, ranking within expert, and scattering into an
[E, C, D] buffer. All shapes static -> jit/vmap/pjit-friendly; the expert dim
E is the EP sharding axis (PartitionSpec over 'model').
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs import MoEConfig
from repro.models.layers import ffn, ffn_init
from repro.utils import round_up


def moe_param_init(key, d_model: int, mcfg: MoEConfig, act: str, dtype) -> Dict:
    kr, ke, ks = jax.random.split(key, 3)
    E, F = mcfg.n_experts, mcfg.d_expert
    s = d_model ** -0.5
    kg, ku, kd = jax.random.split(ke, 3)
    p = {
        "router": (jax.random.normal(kr, (d_model, E)) * s).astype(jnp.float32),
        # stacked expert FFNs (swiglu): [E, D, F] / [E, F, D]
        "wg": (jax.random.normal(kg, (E, d_model, F)) * s).astype(dtype),
        "wu": (jax.random.normal(ku, (E, d_model, F)) * s).astype(dtype),
        "wd": (jax.random.normal(kd, (E, F, d_model)) * F ** -0.5).astype(dtype),
    }
    if mcfg.d_shared:
        p["shared"] = ffn_init(ks, act, d_model, mcfg.d_shared, dtype)
    return p


def capacity(n_tokens: int, mcfg: MoEConfig) -> int:
    c = int(n_tokens * mcfg.top_k * mcfg.capacity_factor / mcfg.n_experts)
    return max(8, round_up(c, 8))


def moe_ffn(x: jax.Array, p: Dict, mcfg: MoEConfig, act: str) -> jax.Array:
    """x: [B, T, D] -> [B, T, D]."""
    B, T, D = x.shape
    if mcfg.dispatch == "einsum":
        # Switch/GSPMD-style one-hot einsum dispatch per batch row: every op
        # is sharding-transparent (no sort/searchsorted/scatter, which GSPMD
        # must replicate) — the fully-local path under slot/batch sharding
        y = jax.vmap(lambda xr: _moe_tokens_einsum(xr, p, mcfg, act))(x)
        return y
    if mcfg.dispatch == "per_row" and B > 1:
        # dispatch independently per batch row: under batch sharding the
        # argsort/scatter stay local to each data shard (no gather)
        y = jax.vmap(lambda xr: _moe_tokens(xr[None], p, mcfg, act))(x)
        return y.reshape(B, T, D)
    return _moe_tokens(x, p, mcfg, act)


def _moe_tokens_einsum(xf: jax.Array, p: Dict, mcfg: MoEConfig,
                       act: str) -> jax.Array:
    """xf: [N, D] one batch row. Iterative-argmax top-k + one-hot positions
    via cumsum + dispatch/combine einsums (the classic TPU MoE formulation;
    memory O(N*E*C) per row)."""
    N, D = xf.shape
    E, K = mcfg.n_experts, mcfg.top_k
    C = capacity(N, mcfg)
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)

    remaining = probs
    count_base = jnp.zeros((E,), jnp.float32)
    disp = jnp.zeros((N, E, C), jnp.float32)     # dispatch one-hot
    comb = jnp.zeros((N, E, C), jnp.float32)     # gate-weighted combine
    topk_gate_sum = jnp.zeros((N,), jnp.float32)
    for _ in range(K):
        idx = jnp.argmax(remaining, axis=-1)                     # [N]
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)       # [N, E]
        gate = (probs * onehot).sum(-1)                          # [N]
        topk_gate_sum = topk_gate_sum + gate   # normalizer (pre-drop, as in
        # position within expert: tokens before me choosing the same expert
        pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot) + count_base[None]
        pos = (pos_in_e * onehot).sum(-1)                        # [N]
        keep = pos < C
        pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[:, None]
        disp = disp + onehot[:, :, None] * pos_oh[:, None, :]
        comb = comb + gate[:, None, None] * onehot[:, :, None] * pos_oh[:, None, :]
        count_base = count_base + onehot.sum(0)
        remaining = remaining * (1.0 - onehot)

    # renormalize by the full top-k gate mass (matches the argsort path)
    comb = comb / jnp.maximum(topk_gate_sum, 1e-9)[:, None, None]
    buf = jnp.einsum("nec,nd->ecd", disp.astype(xf.dtype), xf)
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    out_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["wd"])
    y = jnp.einsum("nec,ecd->nd", comb.astype(xf.dtype), out_buf)
    if "shared" in p:
        y = y + ffn(act, xf, p["shared"])
    return y


def _moe_tokens(x: jax.Array, p: Dict, mcfg: MoEConfig, act: str) -> jax.Array:
    B, T, D = x.shape
    N = B * T
    E, K = mcfg.n_experts, mcfg.top_k
    C = capacity(N, mcfg)
    xf = x.reshape(N, D)

    # --- routing (fp32) ---
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                 # [N, K]
    gate = gate / (gate.sum(-1, keepdims=True) + 1e-9)   # renormalize top-k

    # --- dispatch: sort (token,k) pairs by expert, rank within expert ---
    flat_e = eidx.reshape(-1)                            # [N*K]
    order = jnp.argsort(flat_e)                          # stable
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))   # [E]
    rank = jnp.arange(N * K) - starts[sorted_e]          # position within expert
    keep = rank < C
    rank_c = jnp.minimum(rank, C - 1)
    tok = order // K                                     # source token per pair

    buf = jnp.zeros((E, C, D), x.dtype)
    vals = xf[tok] * keep[:, None].astype(x.dtype)
    buf = buf.at[sorted_e, rank_c].set(vals, mode="drop")

    # --- expert FFN (batched over E; EP shards this einsum over 'model') ---
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    out_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["wd"])

    # --- combine: gather back, unsort, weight by gates ---
    y_pairs_sorted = out_buf[sorted_e, rank_c] * keep[:, None].astype(x.dtype)
    y_pairs = jnp.zeros((N * K, D), x.dtype).at[order].set(y_pairs_sorted)
    y = (y_pairs.reshape(N, K, D)
         * gate.reshape(N, K, 1).astype(x.dtype)).sum(axis=1)

    if "shared" in p:
        y = y + ffn(act, xf, p["shared"])
    return y.reshape(B, T, D)


def aux_load_balance_loss(x: jax.Array, p: Dict, mcfg: MoEConfig) -> jax.Array:
    """Switch-style load-balance auxiliary loss (mean over batch)."""
    N = x.shape[0] * x.shape[1]
    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32)).reshape(N, -1)
    probs = jax.nn.softmax(logits, axis=-1)
    _, eidx = jax.lax.top_k(probs, mcfg.top_k)
    onehot = jax.nn.one_hot(eidx, mcfg.n_experts).sum(1)          # [N, E]
    frac_tokens = onehot.mean(0) / mcfg.top_k    # normalized: uniform -> 1/E
    frac_probs = probs.mean(0)
    return mcfg.n_experts * jnp.sum(frac_tokens * frac_probs)
