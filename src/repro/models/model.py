"""Unified config-driven LM with three execution modes:

  * full       — plain transformer forward (the paper's Llama baseline)
  * segmented  — PRMT/ARMT recurrence, sequential schedule (paper baseline ARMT)
  * segmented + diagonal schedule — the paper's contribution

plus a serving path (`decode_step`) that runs one token against carried state:
'cache' mode (full KV cache — standard decoding) or 'armt' mode (associative
memory + current-segment cache — constant memory in sequence length).

Decode reuses the sequential executor over a single-token "segment", so the
per-layer code is shared and the HLO stays scan-compact for 61-72-layer archs.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.core.memory import mem_read, mem_update
from repro.core.schedule import StackLayout
from repro.core.sequential import run_sequential
from repro.core.diagonal import boundary_states_from_capture, run_diagonal
from repro.models.attention import (attention, cross_kv, decode_attention,
                                    sdpa, causal_mask)
from repro.models.blocks import (block_param_init, block_state_init,
                                 make_apply_block, _is_attn)
from repro.models.layers import ffn, norm, norm_init
from repro.models.mamba import mamba_mixer
from repro.models.moe import moe_ffn


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key: jax.Array, dtype=None) -> Dict:
    dtype = jnp.dtype(dtype or cfg.dtype)
    keys = jax.random.split(key, 16)
    layout = StackLayout.from_config(cfg)
    params: Dict = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                  * 0.02).astype(dtype),
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(keys[1], (cfg.d_model, cfg.vocab))
                          * cfg.d_model ** -0.5).astype(dtype)
    if cfg.armt is not None and cfg.armt.num_mem_tokens > 0:
        params["mem_tokens"] = (jax.random.normal(
            keys[2], (cfg.armt.num_mem_tokens, cfg.d_model)) * 0.02).astype(dtype)
    if not cfg.use_rope and cfg.encoder is not None:
        params["pos_embed"] = (jax.random.normal(
            keys[3], (cfg.max_position, cfg.d_model)) * 0.02).astype(dtype)

    prelude = []
    for j, t in enumerate(layout.prelude):
        prelude.append(block_param_init(jax.random.fold_in(keys[4], j), t, cfg,
                                        dtype, prelude=True))
    params["prelude"] = tuple(prelude)

    pattern = []
    for p_i, t in enumerate(layout.pattern):
        sub = jax.random.split(jax.random.fold_in(keys[5], p_i), layout.n_super)
        stacked = jax.vmap(
            lambda k, _t=t: block_param_init(k, _t, cfg, dtype))(sub)
        pattern.append(stacked)
    params["pattern"] = tuple(pattern)

    if cfg.encoder is not None:
        ek = jax.random.split(keys[6], cfg.encoder.n_layers)
        params["enc"] = {
            "blocks": jax.vmap(
                lambda k: block_param_init(k, "enc", cfg, dtype))(ek),
            "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
            "pos": (jax.random.normal(keys[7], (cfg.encoder.n_frames,
                                                cfg.d_model)) * 0.02).astype(dtype),
        }
    return params


def param_specs(cfg: ArchConfig, dtype=None):
    """Shape/dtype tree without allocation (for dry-runs of 1T-param archs)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.PRNGKey(0))


def decode_state_shapes(cfg: ArchConfig, batch: int, *, serve_mode: str,
                        max_len: int, dtype, per_slot_pos: bool = False):
    """Shape/dtype tree of a decode state without allocation — the
    serve-side sibling of ``param_specs`` above."""
    return jax.eval_shape(
        lambda: decode_state_init(cfg, batch, serve_mode=serve_mode,
                                  max_len=max_len, dtype=dtype,
                                  per_slot_pos=per_slot_pos))


def decode_state_sharding(cfg: ArchConfig, mesh, batch: int, *,
                          serve_mode: str, max_len: int, dtype,
                          per_slot_pos: bool = False,
                          stacked_axis: Optional[str] = None):
    """NamedSharding tree for a decode state on ``mesh``: slots/batch over
    the DP axes, heads/d_model over 'model', pattern-stacked leaves
    optionally over ``stacked_axis`` — the placement the mesh-native serve
    stack (DESIGN.md §10) derives its pools, transplants, and snapshot
    restores from."""
    from repro.parallel import sharding as shd
    shapes = decode_state_shapes(cfg, batch, serve_mode=serve_mode,
                                 max_len=max_len, dtype=dtype,
                                 per_slot_pos=per_slot_pos)
    return shd.decode_state_specs(shapes, mesh, batch,
                                  stacked_axis=stacked_axis)


def init_state(cfg: ArchConfig, batch: int, mode: str, dtype) -> Dict:
    layout = StackLayout.from_config(cfg)
    state: Dict = {"prelude": tuple(
        block_state_init(t, cfg, batch, mode, dtype) for t in layout.prelude)}
    pattern = []
    for t in layout.pattern:
        st = block_state_init(t, cfg, batch, mode, dtype)
        pattern.append(jax.tree_util.tree_map(
            lambda a: jnp.zeros((layout.n_super,) + a.shape, a.dtype), st))
    state["pattern"] = tuple(pattern)
    return state


# forward_hidden takes an `init_state` *argument* (resume from a carried
# state) which shadows the function above inside its body — alias it.
_init_exec_state = init_state


# ---------------------------------------------------------------------------
# Encoder (whisper) — frontend is a stub: callers pass frame *embeddings*
# ---------------------------------------------------------------------------

def encode(params: Dict, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: [B, F, d_model] precomputed frame embeddings -> [B, F, d_model]."""
    x = frames + params["enc"]["pos"][None, :frames.shape[1]].astype(frames.dtype)
    apply = make_apply_block(cfg, mode="full")

    def step(h, blk_p):
        y, _ = apply("enc", blk_p, h, {})
        return y, None

    x, _ = jax.lax.scan(step, x, params["enc"]["blocks"])
    return norm(cfg.norm, x, params["enc"]["final_norm"])


def _fill_cross_kv(params: Dict, cfg: ArchConfig, state: Dict,
                   enc_out: jax.Array) -> Dict:
    """Compute per-decoder-layer cross K/V from encoder output into state."""
    new_pattern = []
    for p_i, t in enumerate(tuple(cfg.block_pattern)):
        st = state["pattern"][p_i]
        if t == "dec":
            ck, cv = jax.vmap(
                lambda xp: cross_kv(enc_out, xp, cfg))(params["pattern"][p_i]["xattn"])
            st = dict(st)
            st["ck"], st["cv"] = ck, cv
        new_pattern.append(st)
    return {"prelude": state["prelude"], "pattern": tuple(new_pattern)}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _resolve_seg_len(cfg: ArchConfig, seg_len: Optional[int],
                     total: Optional[int] = None) -> int:
    if not seg_len:
        seg_len = cfg.armt.segment_len if cfg.armt is not None else 1024
    if total is not None:
        seg_len = min(seg_len, total)
    return seg_len


def embed_segments(params: Dict, cfg: ArchConfig, tokens: jax.Array,
                   seg_len: int, with_mem: bool) -> jax.Array:
    """tokens: [B, S_total] -> [n_seg, B, seg_len (+M), D]."""
    B, total = tokens.shape
    assert total % seg_len == 0, (total, seg_len)
    S = total // seg_len
    segs = tokens.reshape(B, S, seg_len).transpose(1, 0, 2)      # [S,B,T]
    x = params["embed"][segs]                                     # [S,B,T,D]
    if with_mem and "mem_tokens" in params:
        M = params["mem_tokens"].shape[0]
        mem = jnp.broadcast_to(params["mem_tokens"][None, None],
                               (S, B, M, x.shape[-1]))
        x = jnp.concatenate([x, mem], axis=2)
    if "pos_embed" in params:
        x = x + params["pos_embed"][None, None, :x.shape[2]].astype(x.dtype)
    return x


def forward_hidden(params: Dict, cfg: ArchConfig, tokens: jax.Array, *,
                   schedule: str = "diagonal", mode: str = "segmented",
                   seg_len: Optional[int] = None,
                   enc_frames: Optional[jax.Array] = None,
                   ssm_method: str = "assoc",
                   slot_spec=None,
                   grouped_impl: Optional[str] = None,
                   init_state: Optional[Dict] = None,
                   capture_states: bool = False):
    """Returns (hidden [S, B, T, D] — memory-token positions stripped,
    final executor state); with capture_states=True a third output holds
    the recurrent state at every segment boundary (leaves lead with [S];
    boundary c at index c-1) — the capture path for the serving state
    store (serve/state_store.py).

    init_state: resume the executor from a carried state instead of zeros —
    a prefix-cache snapshot or the final state of an earlier forward over a
    prefix of the same stream. The recurrence is layer-local, so splitting
    one long token stream into several forward_hidden calls with the state
    threaded through is exact (per-(layer, segment) applications see
    identical inputs in identical order).

    grouped_impl: 'vmap' | 'fused' override of cfg.grouped_impl — 'fused'
    routes the diagonal executor's per-step grouped launch through the
    Pallas grouped kernels (models/grouped_blocks.py); only meaningful for
    schedule='diagonal'."""
    B = tokens.shape[0]
    dtype = params["embed"].dtype
    if mode == "full":
        seg_len = tokens.shape[1]
        with_mem = False
    else:
        seg_len = _resolve_seg_len(cfg, seg_len, tokens.shape[1])
        with_mem = cfg.armt is not None and cfg.armt.num_mem_tokens > 0

    x = embed_segments(params, cfg, tokens, seg_len, with_mem)
    layout = StackLayout.from_config(cfg)
    if schedule == "auto":
        # Paper Table 9: diagonal wins once the grid is deep in segments; fall
        # back to sequential when the diagonal would be mostly fill/drain.
        schedule = "diagonal" if x.shape[0] >= layout.n_layers else "sequential"
    if init_state is not None:
        state0 = init_state
    else:
        state0 = _init_exec_state(cfg, B, mode, dtype)
        if cfg.encoder is not None:
            assert enc_frames is not None, \
                "whisper needs enc_frames (stub frontend)"
            enc_out = encode(params, cfg, enc_frames)
            state0 = _fill_cross_kv(params, cfg, state0, enc_out)

    block_mode = mode if mode == "full" else "segmented"
    apply = make_apply_block(cfg, mode=block_mode, ssm_method=ssm_method)
    exec_params = {"prelude": params["prelude"], "pattern": params["pattern"]}
    kw = {"remat": cfg.remat != "none", "capture_states": capture_states}
    if schedule == "diagonal":
        run = run_diagonal
        kw["buf_spec"] = slot_spec
        from repro.models.grouped_blocks import resolve_grouped_apply
        ga = resolve_grouped_apply(cfg, grouped_impl, mode=block_mode,
                                   ssm_method=ssm_method,
                                   remat=cfg.remat != "none")
        if ga is not None:
            kw["grouped_apply"] = ga
    else:
        run = run_sequential
    if capture_states:
        ys, fin, captured = run(layout, exec_params, state0, x, apply, **kw)
        if schedule == "diagonal":
            captured = boundary_states_from_capture(layout, captured,
                                                    x.shape[0])
        hidden = ys[:, :, :seg_len] if with_mem else ys
        return hidden, fin, captured
    ys, fin = run(layout, exec_params, state0, x, apply, **kw)
    hidden = ys[:, :, :seg_len] if with_mem else ys
    return hidden, fin


def _head_matmul(params: Dict, cfg: ArchConfig, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", h, params["embed"])
    return jnp.einsum("...d,dv->...v", h, params["head"])


def lm_loss(params: Dict, cfg: ArchConfig, tokens: jax.Array,
            labels: jax.Array, *, schedule: str = "diagonal",
            mode: str = "segmented", seg_len: Optional[int] = None,
            loss_mask: Optional[jax.Array] = None,
            enc_frames: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token NLL. Logits are never materialized for the whole
    sequence — CE is computed per segment inside a scan (DESIGN.md §6.1)."""
    hidden, _ = forward_hidden(params, cfg, tokens, schedule=schedule,
                               mode=mode, seg_len=seg_len,
                               enc_frames=enc_frames)
    S, B, T, D = hidden.shape
    labels_seg = labels.reshape(B, S, T).transpose(1, 0, 2)
    if loss_mask is None:
        mask_seg = jnp.ones((S, B, T), jnp.float32)
    else:
        mask_seg = loss_mask.reshape(B, S, T).transpose(1, 0, 2).astype(jnp.float32)

    # chunk tokens inside each segment too: fp32 logits for a [B, T, V]
    # block of e.g. qwen2.5 (T=1024, V=152k) would be ~10 GB — chunked CE
    # keeps the transient at B*chunk*V (DESIGN.md §6.1)
    chunk = 256
    n_chunks = T // chunk if (T % chunk == 0 and T > chunk) else 1
    Tc = T // n_chunks

    def _chunked(a):
        # [S, B, T, ...] -> [S*n, B, T/n, ...]
        a = a.reshape((S, B, n_chunks, Tc) + a.shape[3:])
        return a.swapaxes(1, 2).reshape((S * n_chunks, B, Tc) + a.shape[4:])

    hidden_c = _chunked(hidden)
    labels_c = _chunked(labels_seg)
    mask_c = _chunked(mask_seg)

    def ce_step(acc, inp):
        h, y, m = inp
        hn = norm(cfg.norm, h, params["final_norm"])
        logits = _head_matmul(params, cfg, hn).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return (acc[0] + nll.sum(), acc[1] + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(ce_step, (jnp.float32(0), jnp.float32(0)),
                                 (hidden_c, labels_c, mask_c))
    return tot / jnp.maximum(cnt, 1.0)


def last_logits(params: Dict, cfg: ArchConfig, hidden: jax.Array) -> jax.Array:
    """Logits of the final position of the final segment. hidden: [S,B,T,D]."""
    h = norm(cfg.norm, hidden[-1, :, -1], params["final_norm"])
    return _head_matmul(params, cfg, h).astype(jnp.float32)


def boundary_logits(params: Dict, cfg: ArchConfig,
                    hidden: jax.Array) -> jax.Array:
    """Logits of the last real-token position of *every* segment:
    hidden [S, B, T, D] -> [S, B, V] fp32. Stored alongside segment-boundary
    snapshots so an exact full-prefix cache hit needs no forward at all."""
    h = norm(cfg.norm, hidden[:, :, -1], params["final_norm"])
    return _head_matmul(params, cfg, h).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Decode / serving
# ---------------------------------------------------------------------------

def decode_state_init(cfg: ArchConfig, batch: int, *, serve_mode: str,
                      max_len: int, dtype, per_slot_pos: bool = False) -> Dict:
    """Per-layer decode state. serve_mode 'cache': full KV cache of max_len.
    serve_mode 'armt': associative memory + current-segment cache.

    per_slot_pos: position as an int32 [batch] vector instead of a scalar —
    each batch row (decode slot) tracks its own in-segment position, so a
    continuous-batching scheduler can pack requests at heterogeneous segment
    phases into one state (serve/scheduler.py)."""
    layout = StackLayout.from_config(cfg)
    hd = cfg.head_dim if cfg.n_heads > 0 else 0
    kv = max(cfg.n_kv_heads, 1)

    def one(t: str) -> Dict:
        st = block_state_init(t, cfg, batch,
                              "segmented" if serve_mode == "armt" else "full",
                              dtype)
        if _is_attn(t) and t != "enc":
            if serve_mode == "armt":
                cache_len = (cfg.armt.segment_len + cfg.armt.num_mem_tokens
                             if cfg.armt else max_len)
            else:
                cache_len = max_len
                st.pop("A", None), st.pop("z", None)
            st["k"] = jnp.zeros((batch, cache_len, kv, hd), dtype)
            st["v"] = jnp.zeros((batch, cache_len, kv, hd), dtype)
        return st

    state = {"prelude": tuple(one(t) for t in layout.prelude)}
    pattern = []
    for t in layout.pattern:
        st = one(t)
        pattern.append(jax.tree_util.tree_map(
            lambda a: jnp.zeros((layout.n_super,) + a.shape, a.dtype), st))
    state["pattern"] = tuple(pattern)
    # position (global or in-segment); [batch] when per-slot
    state["pos"] = jnp.zeros((batch,) if per_slot_pos else (), jnp.int32)
    return state


def mask_decode_state(mask: jax.Array, new_state: Dict, old_state: Dict) -> Dict:
    """Per-row merge of two decode states: rows where ``mask`` is True take
    ``new_state``, others keep ``old_state``. mask: bool [B].

    Handles the three leaf layouts of a decode state: prelude leaves
    [B, ...], pattern leaves [n_super, B, ...], and ``pos`` ([B] or scalar —
    a scalar pos is merged only if the whole mask agrees, which per-slot
    callers never rely on; they use per_slot_pos states)."""
    def sel(axis):
        def one(n, o):
            shape = [1] * n.ndim
            shape[axis] = mask.shape[0]
            return jnp.where(mask.reshape(shape), n, o)
        return one

    out = {
        "prelude": jax.tree_util.tree_map(sel(0), tuple(new_state["prelude"]),
                                          tuple(old_state["prelude"])),
        "pattern": jax.tree_util.tree_map(sel(1), tuple(new_state["pattern"]),
                                          tuple(old_state["pattern"])),
    }
    if "pos" in new_state:
        np_, op = new_state["pos"], old_state["pos"]
        out["pos"] = jnp.where(mask, np_, op) if np_.ndim else jnp.where(
            mask.all(), np_, op)
    return out


def _pos_embed_slice(table: jax.Array, pos: jax.Array, T: int) -> jax.Array:
    """Slice T rows of a learned position table starting at ``pos`` (scalar)
    or per-row at ``pos[b]`` (vector) -> [1 or B, T, D]."""
    if getattr(pos, "ndim", 0) == 1:
        return jax.vmap(lambda p: jax.lax.dynamic_slice_in_dim(
            table, p, T, axis=0))(pos)
    return jax.lax.dynamic_slice_in_dim(table, pos, T, axis=0)[None]


def make_decode_apply(cfg: ArchConfig, serve_mode: str, pos):
    """Block apply for decode: x [B, Tq, D] against per-layer caches."""
    armt_on = serve_mode == "armt" and cfg.armt is not None

    def apply_ffn(t, h, p):
        if t.endswith("moe"):
            return h + moe_ffn(norm(cfg.norm, h, p["ln2"]), p["moe"],
                               cfg.moe, cfg.act)
        if "ffn" in p:
            return h + ffn(cfg.act, norm(cfg.norm, h, p["ln2"]), p["ffn"])
        return h

    def apply(t, p, x, st):
        new = dict(st)
        if _is_attn(t):
            if armt_on:
                x = x + mem_read(p["mem"], st, x, cfg.armt)
            a, kvc = decode_attention(norm(cfg.norm, x, p["ln1"]), p["attn"],
                                      cfg, {"k": st["k"], "v": st["v"]}, pos)
            new["k"], new["v"] = kvc["k"], kvc["v"]
            h = x + a
            if t == "dec":
                from repro.models.attention import cross_attention
                h = h + cross_attention(norm(cfg.norm, h, p["ln_x"]),
                                        p["xattn"], st["ck"], st["cv"], cfg)
            y = apply_ffn(t, h, p)
            return y, new
        if t.startswith("mamba"):
            mix, new_ssm = mamba_mixer(norm(cfg.norm, x, p["ln1"]), p["mixer"],
                                       cfg.ssm,
                                       {"h": st["h"], "conv": st["conv"]})
            y = apply_ffn(t, x + mix, p)
            new.update(new_ssm)
            return y, new
        raise ValueError(t)

    return apply


def decode_step(params: Dict, cfg: ArchConfig, state: Dict,
                tokens: jax.Array, *, serve_mode: str = "armt"):
    """Decoding step. tokens: [B] (one step) or [B, Tq] (chunked prefill) ->
    (logits of the last position [B, V] fp32, new state).

    Runs the layer stack via the sequential executor over a single
    "segment" so the lowered HLO is a compact scan for deep archs.
    """
    layout = StackLayout.from_config(cfg)
    pos = state["pos"]
    toks = tokens if tokens.ndim == 2 else tokens[:, None]
    Tq = toks.shape[1]
    x = params["embed"][toks]                                    # [B,Tq,D]
    if "pos_embed" in params:
        x = x + _pos_embed_slice(params["pos_embed"], pos, Tq).astype(x.dtype)
    apply = make_decode_apply(cfg, serve_mode, pos)
    exec_params = {"prelude": params["prelude"], "pattern": params["pattern"]}
    exec_state = {"prelude": state["prelude"], "pattern": state["pattern"]}
    ys, fin = run_sequential(layout, exec_params, exec_state, x[None], apply)
    h = norm(cfg.norm, ys[0, :, -1], params["final_norm"])
    logits = _head_matmul(params, cfg, h).astype(jnp.float32)
    new_state = {"prelude": fin["prelude"], "pattern": fin["pattern"],
                 "pos": pos + Tq}
    return logits, new_state


def flush_segment(params: Dict, cfg: ArchConfig, state: Dict,
                  slot_mask: Optional[jax.Array] = None):
    """ARMT segment boundary: run the memory tokens through the stack against
    the current-segment cache, delta-update every layer's (A, z), then reset
    the segment cache and position.

    slot_mask: optional bool [B] — flush only those batch rows (decode
    slots), keeping the other rows' state/cache/pos untouched. The flush is
    computed for every row and merged with ``jnp.where`` so heterogeneous
    slots hitting segment boundaries at different steps stay inside one
    jitted step (no host branching); requires a per-slot ``pos`` vector."""
    assert cfg.armt is not None
    assert slot_mask is None or state["pos"].ndim == 1, (
        "flush_segment(slot_mask=...) needs a per-slot pos vector "
        "(decode_state_init(per_slot_pos=True)); a scalar pos cannot be "
        "reset per-row and would silently re-flush every step")
    layout = StackLayout.from_config(cfg)
    M = cfg.armt.num_mem_tokens
    mem = params["mem_tokens"]
    # infer batch from any cache leaf
    first = jax.tree_util.tree_leaves(state["pattern"])[0]
    batch = first.shape[1]
    x = jnp.broadcast_to(mem[None], (batch, M, mem.shape[-1]))
    if "pos_embed" in params:
        x = x + _pos_embed_slice(params["pos_embed"], state["pos"],
                                 M).astype(x.dtype)

    pos = state["pos"]
    base_apply = make_decode_apply(cfg, "armt", pos)

    def apply(t, p, xx, st):
        y, new = base_apply(t, p, xx, st)
        if _is_attn(t) and t != "enc" and "A" in st:
            upd = mem_update(p["mem"], {"A": st["A"], "z": st["z"]}, y, cfg.armt)
            new = dict(new)
            new.update(upd)
            # reset current-segment cache
            new["k"] = jnp.zeros_like(st["k"])
            new["v"] = jnp.zeros_like(st["v"])
        return y, new

    exec_params = {"prelude": params["prelude"], "pattern": params["pattern"]}
    exec_state = {"prelude": state["prelude"], "pattern": state["pattern"]}
    _, fin = run_sequential(layout, exec_params, exec_state, x[None], apply)
    flushed = {"prelude": fin["prelude"], "pattern": fin["pattern"],
               "pos": jnp.zeros_like(state["pos"])}
    if slot_mask is None:
        return flushed
    return mask_decode_state(slot_mask, flushed, state)
