"""Per-type block parameter init, state init, and application.

Block types (cfg.layer_types entries):
  attn       pre-norm attention + dense FFN              (+ ARMT memory)
  attn_moe   pre-norm attention + MoE FFN                (+ ARMT memory)
  mamba      pre-norm mamba mixer [+ dense FFN if d_ff]  (SSM state)
  mamba_moe  pre-norm mamba mixer + MoE FFN              (SSM state)
  enc        bidirectional attention + MLP (whisper encoder; stateless)
  dec        causal self-attn + cross-attn + MLP         (+ ARMT memory; cross
             K/V carried as constant state)

``make_apply_block(cfg, mode)`` binds a closure with the executor signature
(btype, params, x, state) -> (y, new_state); the same closure serves both
sequential and diagonal executors (the reordering is the only difference).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.memory import mem_param_init, mem_read, mem_state_init, mem_update
from repro.models.attention import (attention, attn_param_init, cross_attention)
from repro.models.layers import ffn, ffn_init, norm, norm_init
from repro.models.mamba import (mamba_mixer, mamba_param_init, mamba_state_init)
from repro.models.moe import moe_ffn, moe_param_init


def _is_attn(t: str) -> bool:
    return t in ("attn", "attn_moe", "dec", "enc")


def block_d_ff(cfg, t: str, prelude: bool) -> int:
    if t.endswith("moe"):
        return 0                      # MoE replaces the dense FFN
    if prelude and cfg.prelude_d_ff:
        return cfg.prelude_d_ff
    return cfg.d_ff


def block_param_init(key, t: str, cfg, dtype, *, prelude: bool = False) -> Dict:
    ks = jax.random.split(key, 8)
    p: Dict = {"ln1": norm_init(cfg.norm, cfg.d_model, dtype)}
    if _is_attn(t):
        p["attn"] = attn_param_init(ks[0], cfg, dtype)
        if cfg.armt is not None and t != "enc":
            p["mem"] = mem_param_init(ks[1], cfg.d_model, cfg.armt, dtype)
    if t == "dec":
        p["ln_x"] = norm_init(cfg.norm, cfg.d_model, dtype)
        p["xattn"] = attn_param_init(ks[2], cfg, dtype, cross=True)
    if t.startswith("mamba"):
        p["mixer"] = mamba_param_init(ks[3], cfg.d_model, cfg.ssm, dtype)
    if t.endswith("moe"):
        p["ln2"] = norm_init(cfg.norm, cfg.d_model, dtype)
        p["moe"] = moe_param_init(ks[4], cfg.d_model, cfg.moe, cfg.act, dtype)
    else:
        dff = block_d_ff(cfg, t, prelude)
        if dff > 0:
            p["ln2"] = norm_init(cfg.norm, cfg.d_model, dtype)
            p["ffn"] = ffn_init(ks[5], cfg.act, cfg.d_model, dff, dtype,
                                bias=(cfg.norm == "layernorm"))
    return p


def block_state_init(t: str, cfg, batch: int, mode: str, dtype) -> Dict:
    """Layer-local recurrent state for segmented execution. mode: segmented|full."""
    st: Dict = {}
    if mode == "segmented":
        if cfg.armt is not None and _is_attn(t) and t != "enc":
            st.update(mem_state_init(batch, cfg.d_model, cfg.armt, dtype))
        if t.startswith("mamba"):
            st.update(mamba_state_init(batch, cfg.d_model, cfg.ssm, dtype))
    else:
        if t.startswith("mamba"):  # full mode still needs zero ssm state
            st.update(mamba_state_init(batch, cfg.d_model, cfg.ssm, dtype))
    if t == "dec" and cfg.encoder is not None:
        hd, kv, F = cfg.head_dim, cfg.n_kv_heads, cfg.encoder.n_frames
        st["ck"] = jnp.zeros((batch, F, kv, hd), dtype)
        st["cv"] = jnp.zeros((batch, F, kv, hd), dtype)
    return st


def make_apply_block(cfg, *, mode: str = "segmented", ssm_method: str = "scan"):
    """Returns apply_block(btype, p, x, state) -> (y, new_state).

    mode='segmented': ARMT memory active (read before layer, delta-rule update
    from memory-token outputs — paper eq. 2); mode='full': plain transformer.
    """
    armt_on = cfg.armt is not None and mode == "segmented"
    M = cfg.armt.num_mem_tokens if armt_on else 0
    cb = getattr(cfg, "cell_block", 0)

    def blockwise_ffn(h, p):
        # BPT-style query-blocked FFN (DESIGN.md §15): the FFN is
        # position-local, so splitting the token axis into cell_block
        # chunks and rematerializing per chunk bounds the live
        # intermediate to O(cell_block * d_ff) instead of O(T * d_ff).
        # lax.map keeps the chunks sequential (one block's activations
        # alive at a time); the pad tail is dropped after the reshape.
        T = h.shape[-2]
        nb = -(-T // cb)
        hp = jnp.pad(h, [(0, 0)] * (h.ndim - 2)
                     + [(0, nb * cb - T), (0, 0)])
        hb = jnp.moveaxis(
            hp.reshape(hp.shape[:-2] + (nb, cb, hp.shape[-1])), -3, 0)
        f = jax.checkpoint(
            lambda blk: ffn(cfg.act, norm(cfg.norm, blk, p["ln2"]),
                            p["ffn"]))
        yb = jnp.moveaxis(jax.lax.map(f, hb), 0, -3)
        return yb.reshape(hp.shape)[..., :T, :]

    def apply_ffn(t: str, h, p):
        if t.endswith("moe"):
            return h + moe_ffn(norm(cfg.norm, h, p["ln2"]), p["moe"],
                               cfg.moe, cfg.act)
        if "ffn" in p:
            if cb > 0 and h.shape[-2] > cb:
                return h + blockwise_ffn(h, p)
            return h + ffn(cfg.act, norm(cfg.norm, h, p["ln2"]), p["ffn"])
        return h

    def apply_block(t: str, p, x, state):
        new_state = dict(state)
        if _is_attn(t):
            use_mem = armt_on and t != "enc"
            if use_mem:
                x = x + mem_read(p["mem"], state, x, cfg.armt)
            a = attention(norm(cfg.norm, x, p["ln1"]), p["attn"], cfg,
                          bidirectional=(t == "enc"))
            h = x + a
            if t == "dec":
                h = h + cross_attention(norm(cfg.norm, h, p["ln_x"]), p["xattn"],
                                        state["ck"], state["cv"], cfg)
            y = apply_ffn(t, h, p)
            if use_mem and M > 0:
                upd = mem_update(p["mem"], {"A": state["A"], "z": state["z"]},
                                 y[:, -M:, :], cfg.armt)
                new_state.update(upd)
            return y, new_state

        if t.startswith("mamba"):
            mix, new_ssm = mamba_mixer(
                norm(cfg.norm, x, p["ln1"]), p["mixer"], cfg.ssm,
                {"h": state["h"], "conv": state["conv"]}, method=ssm_method)
            h = x + mix
            y = apply_ffn(t, h, p)
            new_state.update(new_ssm)
            return y, new_state

        raise ValueError(f"unknown block type {t!r}")

    return apply_block
