"""Roofline terms from the compiled dry-run artifact.

  compute_term    = HLO_FLOPs / peak_FLOP/s          (per device)
  memory_term     = HLO_bytes / HBM_bw               (per device)
  collective_term = collective_wire_bytes / link_bw  (per device)

XLA's ``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies ONCE,
so for scan-based models (every executor here is a scan) it undercounts by
the trip count. We therefore walk the post-SPMD HLO text ourselves:

  * build the call graph (ENTRY -> while bodies / calls / conditionals) and
    propagate an execution-count multiplier (trip counts parsed from each
    while condition's loop bound constant);
  * FLOPs: every ``dot`` op = 2 * prod(out_shape) * prod(contracted dims),
    times its computation's multiplier (fusion bodies are traversed for dots
    too — XLA does not fuse dots away);
  * bytes: materialized-op outputs (fusions counted as one op, internals
    skipped) * 2 (write + subsequent read), times multiplier. This is an
    estimate: CPU lowering upcasts bf16 dots to f32 (TPU would not), so the
    memory term carries ~2x uncertainty — documented in EXPERIMENTS.md.
  * collectives: all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute output bytes -> ring-algorithm wire bytes, times
    multiplier, with replica-group sizes parsed per op.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]+|pred)\[([0-9,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
# ops whose outputs we do not count as memory traffic
_BYTES_SKIP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "while", "conditional", "call", "after-all", "token",
    "partition-id", "replica-id", "iota", "convert", "copy-start",
    "copy-done", "add-dependency", "domain", "opt-barrier",
}


def shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class _Instr:
    name: str
    op: str
    out_shape: str
    line: str


class HloAnalyzer:
    def __init__(self, hlo: str, total_devices: int):
        self.total_devices = total_devices
        self.comps: Dict[str, List[_Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo)
        self.mult = self._multipliers()

    # ---------------- parsing ----------------

    _COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
    _INSTR_RE = re.compile(
        r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
        r"((?:\([^)]*\))|(?:(?:[a-z]+[0-9]*|pred)\[[0-9,]*\](?:\{[^}]*\})?))\s*"
        r"([\w\-]+)\(")

    def _parse(self, hlo: str) -> None:
        cur: Optional[str] = None
        for line in hlo.splitlines():
            if not line.startswith(" ") and "{" in line and "->" in line:
                m = self._COMP_RE.match(line.strip())
                if m:
                    cur = m.group(2)
                    self.comps[cur] = []
                    if m.group(1):
                        self.entry = cur
                    continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = self._INSTR_RE.match(line)
            if m:
                self.comps[cur].append(
                    _Instr(m.group(1), m.group(3), m.group(2), line))

    def _trip_count(self, cond_comp: str) -> int:
        """Loop bound: the largest integer constant in the condition body."""
        best = 1
        for ins in self.comps.get(cond_comp, []):
            if ins.op == "constant":
                c = re.search(r"constant\((\d+)\)", ins.line)
                if c:
                    best = max(best, int(c.group(1)))
        return best

    def _multipliers(self) -> Dict[str, float]:
        """Execution count per computation, from ENTRY through whiles/calls."""
        mult: Dict[str, float] = {}
        if self.entry is None:
            return mult
        stack: List[Tuple[str, float]] = [(self.entry, 1.0)]
        while stack:
            comp, m = stack.pop()
            mult[comp] = mult.get(comp, 0.0) + m
            for ins in self.comps.get(comp, []):
                if ins.op == "while":
                    cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
                    bm = re.search(r"body=%?([\w.\-]+)", ins.line)
                    if cm and bm:
                        trips = self._trip_count(cm.group(1))
                        stack.append((bm.group(1), m * trips))
                elif ins.op == "call":
                    tm = re.search(r"to_apply=%?([\w.\-]+)", ins.line)
                    if tm:
                        stack.append((tm.group(1), m))
                elif ins.op == "conditional":
                    for br in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                         r"(?:true|false)_computation=%?([\w.\-]+))",
                                         ins.line):
                        names = (br[0] or br[1]).split(",")
                        for n in names:
                            n = n.strip().lstrip("%")
                            if n:
                                stack.append((n, m))  # upper bound: both branches
        return mult

    def _fusion_callees(self) -> Dict[str, float]:
        """Multipliers for fusion computations (for dot counting inside them)."""
        out: Dict[str, float] = {}
        for comp, m in self.mult.items():
            for ins in self.comps.get(comp, []):
                if ins.op == "fusion":
                    cm = re.search(r"calls=%?([\w.\-]+)", ins.line)
                    if cm:
                        out[cm.group(1)] = out.get(cm.group(1), 0.0) + m
        return out

    # ---------------- metrics ----------------

    def flops(self) -> float:
        comp_mults = dict(self.mult)
        for c, m in self._fusion_callees().items():
            comp_mults[c] = comp_mults.get(c, 0.0) + m
        total = 0.0
        for comp, m in comp_mults.items():
            for ins in self.comps.get(comp, []):
                if ins.op not in ("dot", "convolution"):
                    continue
                out_elems = 1
                for d in _shape_dims(ins.out_shape):
                    out_elems *= d
                if ins.op == "dot":
                    om = re.search(r"dot\(([^)]*)\)", ins.line)
                    lhs_dims: List[int] = []
                    if om:
                        shapes = _SHAPE_RE.findall(om.group(1))
                        # operand list may or may not embed shapes; fall back
                        if shapes:
                            dims = shapes[0][1]
                            lhs_dims = ([int(d) for d in dims.split(",")]
                                        if dims else [])
                    if not lhs_dims:
                        # operands given as %refs only: find producer shape
                        ref = re.search(r"dot\(%?([\w.\-]+)", ins.line)
                        lhs_dims = self._producer_dims(comp, ref.group(1)) if ref else []
                    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
                    k = 1
                    if cm and cm.group(1) and lhs_dims:
                        for ci in cm.group(1).split(","):
                            ci = int(ci)
                            if ci < len(lhs_dims):
                                k *= lhs_dims[ci]
                    total += 2.0 * out_elems * k * m
                else:  # convolution: 2 * out * kernel_elems_per_output
                    km = re.search(r"convolution\(([^)]*)\)", ins.line)
                    kshape = _SHAPE_RE.findall(km.group(1))[-1] if km else None
                    kelems = 1
                    if kshape and kshape[1]:
                        for d in kshape[1].split(","):
                            kelems *= int(d)
                    total += 2.0 * out_elems * kelems * m
        return total

    def _producer_dims(self, comp: str, ref: str) -> List[int]:
        for ins in self.comps.get(comp, []):
            if ins.name == ref:
                return _shape_dims(ins.out_shape)
        return []

    def bytes_accessed(self, *, exclude_seq_sq: int = 0) -> float:
        """exclude_seq_sq=T: drop ops whose trailing two dims are both T —
        the attention-score chain, which the (validated) Pallas flash kernel
        keeps in VMEM on TPU. Used for the flash-adjusted memory term."""
        total = 0.0
        for comp, m in self.mult.items():
            for ins in self.comps.get(comp, []):
                if ins.op in _BYTES_SKIP or ins.op in _COLL_KINDS:
                    continue
                if exclude_seq_sq:
                    dims = _shape_dims(ins.out_shape)
                    if (len(dims) >= 2 and dims[-1] == exclude_seq_sq
                            and dims[-2] == exclude_seq_sq):
                        continue
                if ins.op == "dynamic-update-slice":
                    # in-place update (donated/aliased buffers): traffic is
                    # the written slice, not the whole buffer
                    ops = re.search(r"dynamic-update-slice\(([^)]*)\)", ins.line)
                    b = 0
                    if ops:
                        shapes = _SHAPE_RE.findall(ops.group(1))
                        if len(shapes) >= 2:
                            dt, dims = shapes[1]
                            n = 1
                            for d in (dims.split(",") if dims else []):
                                n *= int(d)
                            b = n * _DTYPE_BYTES.get(dt, 0)
                        else:
                            refs = re.findall(r"%?([\w.\-]+)",
                                              ops.group(1))
                            if len(refs) >= 2:
                                dims = self._producer_dims(comp, refs[1])
                                n = 1
                                for d in dims:
                                    n *= d
                                b = n * 4
                    total += 2.0 * b * m
                    continue
                total += 2.0 * shape_bytes(ins.out_shape) * m
        return total

    def collectives(self) -> "CollectiveStats":
        stats = CollectiveStats()
        for comp, m in self.mult.items():
            for ins in self.comps.get(comp, []):
                kind = None
                for k in _COLL_KINDS:
                    if ins.op == k or ins.op == k + "-start":
                        kind = k
                        break
                if kind is None:
                    continue
                out_b = shape_bytes(ins.out_shape)
                group = _group_size(ins.line, self.total_devices)
                wb = wire_bytes(kind, out_b, group) * m
                stats.per_op[kind] = stats.per_op.get(kind, 0.0) + wb
                stats.count[kind] = stats.count.get(kind, 0) + int(m)
                stats.total_wire_bytes += wb
        return stats


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def wire_bytes(kind: str, out_bytes: int, group: int) -> float:
    """Per-device ICI wire bytes for ring algorithms."""
    if group <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (group - 1) / group * out_bytes
    if kind == "all-gather":          # out = gathered full buffer
        return (group - 1) / group * out_bytes
    if kind == "reduce-scatter":      # out = local shard
        return (group - 1) * out_bytes
    if kind == "all-to-all":
        return (group - 1) / group * out_bytes
    if kind == "collective-permute":
        return float(out_bytes)
    return float(out_bytes)


@dataclass
class CollectiveStats:
    per_op: Dict[str, float] = field(default_factory=dict)
    count: Dict[str, int] = field(default_factory=dict)
    total_wire_bytes: float = 0.0


def collect_collectives(hlo: str, total_devices: int) -> CollectiveStats:
    return HloAnalyzer(hlo, total_devices).collectives()


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    roofline_fraction: float

    def to_dict(self):
        return self.__dict__.copy()


def roofline_terms(analyzer: HloAnalyzer, n_devices: int,
                   model_flops: float) -> Roofline:
    flops_dev = analyzer.flops()
    bytes_dev = analyzer.bytes_accessed()
    coll = analyzer.collectives()
    wire_dev = coll.total_wire_bytes
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = wire_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    total_flops = flops_dev * n_devices
    useful = model_flops / total_flops if total_flops else 0.0
    # fraction of roofline: useful-FLOPs time at peak over the bound term sum
    ideal_s = (model_flops / n_devices) / PEAK_FLOPS_BF16
    bound_s = max(terms.values())
    frac = ideal_s / bound_s if bound_s > 0 else 0.0
    return Roofline(flops_dev, bytes_dev, wire_dev, compute_s, memory_s,
                    collective_s, dominant, model_flops, useful, frac)
