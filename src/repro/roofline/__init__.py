from repro.roofline.analysis import (CollectiveStats, HloAnalyzer, Roofline,
                                     collect_collectives, roofline_terms,
                                     shape_bytes, wire_bytes)
from repro.roofline.model_math import model_flops, param_counts
