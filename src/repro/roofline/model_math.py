"""Analytic parameter counts and MODEL_FLOPS (6*N*D train / 2*N*D inference,
N = active params for MoE) — the 'useful FLOPs' reference for the roofline."""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np

from repro.configs import ArchConfig, ShapeSpec
from repro.models.model import param_specs


def _leaf_count(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))


def param_counts(cfg: ArchConfig) -> Tuple[int, int]:
    """(total_params, active_params_per_token)."""
    shapes = param_specs(cfg)
    total = _leaf_count(shapes)
    active = total
    if cfg.moe is not None:
        # routed experts: only top_k of n_experts are active per token
        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        routed = sum(int(np.prod(l.shape)) for path, l in flat
                     if any(getattr(k, "key", None) == "moe" for k in path)
                     and str(getattr(path[-1], "key", "")) in ("wg", "wu", "wd"))
        active = total - routed + int(routed * cfg.moe.top_k / cfg.moe.n_experts)
    return total, active


def embedding_params(cfg: ArchConfig) -> int:
    n = cfg.vocab * cfg.d_model
    if not cfg.tie_embeddings:
        n *= 2
    return n


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Useful model FLOPs for one step of this cell (whole-job, all devices).

    train:   6 * N_active * tokens   (fwd 2ND + bwd 4ND)
    prefill: 2 * N_active * tokens
    decode:  2 * N_active * batch    (one token per sequence)
    Embedding-table params are excluded from N (lookup, not matmul); the
    unembedding projection is included.
    """
    total, active = param_counts(cfg)
    n = active - cfg.vocab * cfg.d_model   # exclude the lookup table
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch
