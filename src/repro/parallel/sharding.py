"""Sharding rules: path-based PartitionSpecs for params, optimizer state
(ZeRO-1), batches, and decode states.

Axes: 'pod' (outer DP, multi-pod only), 'data' (DP), 'model' (TP/EP),
'stage' (diagonal-as-pipeline slot sharding, DESIGN.md §6.2 — also the
stacked per-layer dim of pattern params).
Rules only annotate *arguments*; internal activations are propagated by
GSPMD. Dims that do not divide the axis size fall back to replication —
GSPMD stays correct, and each fallback emits one structured warning line
(``repro.parallel.sharding`` logger, deduplicated) naming the leaf/dim so a
sharding regression is visible in serve logs and benchmark output rather
than silently costing replicated memory/compute (the §Perf hillclimb then
fixes the ones that matter, e.g. qwen2.5's 40 heads).
"""
from __future__ import annotations

import logging
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_log = logging.getLogger("repro.parallel.sharding")
_warned: set = set()


def _warn_replicated(kind: str, leaf: str, dim: int, size: int,
                     axis: str, axis_size: int) -> None:
    """One structured line per distinct fallback: a dim a rule *wanted* to
    shard does not divide its mesh axis, so it is replicated instead.

    Every occurrence also increments the default telemetry registry's
    ``sharding_fallback_total`` counter (labeled kind/leaf/dim/axis,
    DESIGN.md §13) — the counter is NOT deduped, so a fallback re-hit on
    every trace still counts, while the log line stays one per distinct
    site."""
    _registry().inc("sharding_fallback_total", kind=kind, leaf=leaf,
                    dim=dim, axis=axis)
    key = (kind, leaf, dim, size, axis, axis_size)
    if key in _warned:
        return
    _warned.add(key)
    _log.warning(
        "sharding-fallback kind=%s leaf=%s dim=%d size=%d axis=%s "
        "axis_size=%d -> replicated", kind, leaf, dim, size, axis, axis_size)


def reset_fallback_warnings() -> None:
    """Clear the warning dedup set AND the registry's fallback counter —
    one reset for both views of the same events. The inverse direction is
    unified too: ``_registry()`` installs ``_warned.clear`` as a reset
    hook, so ``default_registry().reset()`` clears the dedup set."""
    _warned.clear()
    _registry().remove_series("sharding_fallback_total")


_hooked = False


def _registry():
    """The default telemetry registry, with this module's dedup set wired
    into its reset on first use. Imported lazily at call time — the serve
    package's __init__ imports the engine, which imports this module, so a
    module-level import either way would be a cycle."""
    global _hooked
    from repro.serve.telemetry import default_registry
    reg = default_registry()
    if not _hooked:
        reg.register_reset_hook(_warned.clear)
        _hooked = True
    return reg


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def tp_size(mesh: Mesh) -> int:
    return int(mesh.shape["model"]) if "model" in mesh.axis_names else 1


def stage_size(mesh: Mesh) -> int:
    return int(mesh.shape["stage"]) if "stage" in mesh.axis_names else 1


def batch_axes(mesh: Mesh, batch: int, *, leaf: str = ""):
    """Largest prefix of dp axes whose product divides the batch."""
    axes = []
    prod = 1
    for a in dp_axes(mesh):
        if batch % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    if batch > 1 and prod < dp_size(mesh):
        # batch > 1 can't fill the dp axes — rows are (partially) replicated.
        # batch == 1 (e.g. scheduler admission prefill) is by design, not a
        # regression, so it stays quiet.
        _warn_replicated("batch", leaf or "batch", 0, batch,
                         "x".join(dp_axes(mesh)) or "data", dp_size(mesh))
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def _path_names(path) -> list:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(f"[{k.idx}]")
        elif hasattr(k, "name"):
            names.append(str(k.name))
    return names


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def param_leaf_spec(names, shape, tp: int) -> P:
    """PartitionSpec for one parameter leaf, ignoring any stacked leading dim
    (caller prepends None for stacked pattern/enc-block params)."""
    if tp <= 1:   # no 'model' axis in this mesh (e.g. pure stage meshes)
        return P(*([None] * len(shape)))
    last = names[-1]
    leaf = ".".join(names)
    in_mem = "mem" in names
    in_moe = "moe" in names
    in_mixer = "mixer" in names

    def fallback(dim: int) -> None:
        _warn_replicated("param", leaf, dim, shape[dim], "model", tp)

    if last == "embed":
        if _div(shape[0], tp):
            return P("model", None)
        if _div(shape[1], tp):
            return P(None, "model")
        fallback(0)
        return P(None, None)
    if last == "head":
        if _div(shape[1], tp):
            return P(None, "model")
        if _div(shape[0], tp):
            return P("model", None)
        fallback(1)
        return P(None, None)
    if last in ("mem_tokens", "pos_embed", "pos", "router"):
        return P(*([None] * len(shape)))

    if in_moe and "shared" not in names and last in ("wg", "wu", "wd"):
        E = shape[0]
        if _div(E, tp):
            return P("model", None, None)          # expert parallelism
        # fall back: shard the FFN hidden dim
        if last in ("wg", "wu"):
            if _div(shape[2], tp):
                return P(None, None, "model")
            fallback(2)
            return P(None, None, None)
        if _div(shape[1], tp):
            return P(None, "model", None)
        fallback(1)
        return P(None, None, None)

    if in_mem:
        if last == "wv" and _div(shape[1], tp):
            return P(None, "model")
        return P(*([None] * len(shape)))           # wq/wk/wb tiny -> replicate

    if in_mixer:
        table = {
            "in_proj": P(None, "model"), "conv_w": P(None, "model"),
            "x_proj": P("model", None), "dt_proj": P(None, "model"),
            "A_log": P("model", None), "out_proj": P("model", None),
            "D": P("model"), "conv_b": P("model"), "dt_bias": P("model"),
        }
        spec = table.get(last, P(*([None] * len(shape))))
        # verify divisibility on each sharded dim; else replicate
        for d, ax in enumerate(spec):
            if ax is not None and not _div(shape[d], tp):
                fallback(d)
                return P(*([None] * len(shape)))
        return spec

    # attention / dense FFN projections
    if last in ("wq", "wk", "wv", "wg", "wu", "wi"):   # column parallel
        if _div(shape[1], tp):
            return P(None, "model")
        fallback(1)
        return P(None, None)
    if last in ("wo", "wd"):                           # row parallel
        if _div(shape[0], tp):
            return P("model", None)
        fallback(0)
        return P(None, None)
    return P(*([None] * len(shape)))                   # norms, biases, misc


def param_specs(params_shape: Any, mesh: Mesh, *, fsdp: bool = False,
                stacked_axis: str = None) -> Any:
    """Tree of NamedSharding matching a (ShapeDtypeStruct) param tree.

    fsdp=True additionally shards the first replicated, dp-divisible dim of
    every leaf over the DP axes (ZeRO-3/FSDP — required to fit the 1T-param
    MoE and the 398B hybrid; GSPMD inserts the per-layer all-gathers).

    stacked_axis: shard the stacked per-layer dim of pattern params over this
    mesh axis — the 'diagonal-as-pipeline' slot sharding (DESIGN.md §6.2)."""
    tp = tp_size(mesh)
    dp = dp_axes(mesh)
    dsz = dp_size(mesh)

    def one(path, leaf):
        names = _path_names(path)
        stacked = ("pattern" in names) or ("enc" in names and "blocks" in names)
        shape = leaf.shape[1:] if stacked else leaf.shape
        spec = list(param_leaf_spec(names, shape, tp))
        if stacked:
            ax = (stacked_axis if stacked_axis
                  and _div(leaf.shape[0], mesh.shape[stacked_axis]) else None)
            if stacked_axis and ax is None:
                _warn_replicated("param", ".".join(names), 0, leaf.shape[0],
                                 stacked_axis, int(mesh.shape[stacked_axis]))
            spec = [ax] + spec
        if fsdp and dp:
            for d in range(len(leaf.shape)):
                if spec[d] is None and _div(leaf.shape[d], dsz):
                    spec[d] = dp if len(dp) > 1 else dp[0]
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def zero1_specs(params_shape: Any, mesh: Mesh) -> Any:
    """Optimizer-moment shardings: the param spec + the first replicated,
    divisible dim additionally sharded over the DP axes (ZeRO-1)."""
    return param_specs(params_shape, mesh, fsdp=True)


def opt_state_specs(opt_shape: Any, params_shape: Any, mesh: Mesh, *,
                    zero1: bool = True) -> Any:
    """Shardings for the optimizer state tree. Handles Adafactor-style
    factored second moments (leaves named vr/vc are small -> replicated)."""
    base = (zero1_specs(params_shape, mesh) if zero1
            else param_specs(params_shape, mesh))
    rep = NamedSharding(mesh, P())
    v_shape = opt_shape["v"]
    flat_base = {tuple(_path_names(p)): s for p, s in
                 jax.tree_util.tree_flatten_with_path(base)[0]}

    def one_v(path, leaf):
        names = _path_names(path)
        if names and names[-1] in ("vr", "vc"):
            return rep
        key = tuple(names)
        return flat_base.get(key, rep)

    v_specs = jax.tree_util.tree_map_with_path(one_v, v_shape)
    return {"m": base, "v": v_specs, "step": rep}


def batch_specs(mesh: Mesh, batch_shape: Any) -> Any:
    """Shardings for a batch dict of arrays whose dim 0 is the batch."""
    def one(leaf):
        ax = batch_axes(mesh, leaf.shape[0])
        return NamedSharding(mesh, P(ax, *([None] * (len(leaf.shape) - 1))))
    return jax.tree_util.tree_map(one, batch_shape)


def decode_state_specs(state_shape: Any, mesh: Mesh, batch: int, *,
                       stacked_axis: Optional[str] = None) -> Any:
    """Shardings for decode state trees (k/v caches, A/z, ssm h/conv, pos).

    Serving placement (DESIGN.md §10): the batch dim — the scheduler's decode
    *slots* — shards over the DP axes, head/d_model-like dims over 'model',
    tiny per-leaf remainders replicate (with a structured fallback warning).
    ``pos`` may be a scalar (single-request decode, replicated) or an int32
    [batch] per-slot vector (scheduler pools) which shards with the slots.

    stacked_axis: shard the leading n_super dim of pattern leaves over this
    mesh axis, mirroring ``param_specs(stacked_axis=...)`` so a stage-sharded
    engine keeps each stage's recurrent state local to its own layers.
    """
    tp = tp_size(mesh)

    def one(path, leaf):
        names = _path_names(path)
        last = names[-1]
        leaf_name = ".".join(names)
        bax = batch_axes(mesh, batch, leaf=leaf_name)
        if last == "pos":
            # scalar: replicated; per-slot [batch] vector: sharded with slots
            spec = [bax] if len(leaf.shape) == 1 else []
            return NamedSharding(mesh, P(*spec))
        stacked = "pattern" in names
        shape = leaf.shape[1:] if stacked else leaf.shape
        if last in ("k", "v", "ck", "cv"):          # [B, S, kv, hd]
            if tp <= 1:   # no 'model' axis in this mesh (e.g. data,stage)
                spec = [bax, None, None, None]
            elif _div(shape[2], tp):
                spec = [bax, None, "model", None]
            elif _div(shape[1], tp):
                # kv heads don't divide TP: shard the *sequence* dim of the
                # cache instead (a 32k cache replicated 16x would blow HBM)
                spec = [bax, "model", None, None]
            else:
                _warn_replicated("decode_state", leaf_name, 2, shape[2],
                                 "model", tp)
                spec = [bax, None, None, None]
        elif last in ("A", "h", "conv"):
            # model-dim placement of the recurrent leaves:
            #   A [B, P, dv] dim 2 / h [B, dI, dS] dim 1 / conv [B, dc-1, dI]
            #   dim 2 — replication here silently multiplies the serving
            #   state the ARMT/SSM path depends on, so it warns like k/v
            d = 1 if last == "h" else 2
            spec = [bax] + [None] * (len(shape) - 1)
            if tp > 1:
                if _div(shape[d], tp):
                    spec[d] = "model"
                else:
                    _warn_replicated("decode_state", leaf_name, d, shape[d],
                                     "model", tp)
        elif last == "z":                           # [B, P]
            spec = [bax, None]
        else:
            spec = [bax] + [None] * (len(shape) - 1)
        if stacked:
            ax = (stacked_axis if stacked_axis
                  and _div(leaf.shape[0], mesh.shape[stacked_axis]) else None)
            if stacked_axis and ax is None:
                _warn_replicated("decode_state", leaf_name, 0, leaf.shape[0],
                                 stacked_axis, int(mesh.shape[stacked_axis]))
            spec = [ax] + spec
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, state_shape)


def slot_buf_spec(mesh: Mesh, n_layers: int, batch: int) -> Optional[P]:
    """PartitionSpec for the diagonal executor's slot buffer [L, B, T, D]:
    slots over 'stage' (diagonal-as-pipeline, DESIGN.md §6.2) and the batch
    over the DP axes. Returns None when the mesh offers neither (the
    constraint would be a no-op)."""
    stage = None
    if "stage" in mesh.axis_names:
        if _div(n_layers, stage_size(mesh)):
            stage = "stage"
        else:
            _warn_replicated("slot_buf", "buf", 0, n_layers, "stage",
                             stage_size(mesh))
    bax = batch_axes(mesh, batch, leaf="slot_buf")
    if stage is None and bax is None:
        return None
    return P(stage, bax, None, None)


def pipeline_carry_specs(carry_shape: Any, mesh: Mesh, n_layers: int,
                         batch: int, *,
                         stacked_axis: Optional[str] = None) -> Any:
    """NamedShardings for a suspended diagonal-pipeline carry and its
    read-only ``xs`` input (DESIGN.md §11) — mesh-safe per the §10 rules:

      * ``buf`` [L, B, T, D] — ``slot_buf_spec`` (slots over 'stage',
        batch over the DP axes);
      * ``state`` — the executor state tree via the decode-state rules
        (A/z/h/conv placement identical to the serving pool, stacked
        pattern leaves over ``stacked_axis``);
      * ``ys`` / ``xs`` [S(+L-1), B, T, D] — batch over the DP axes,
        segment/step dims replicated (every step reads one segment);
      * ``win`` [W, B, T, D] (streaming carries, DESIGN.md §15) — the
        rolling drained-segment window, laid out exactly like ``ys``
        (window dim replicated, batch over the DP axes);
      * ``brow`` [S, B, D] (streaming carries) — retained boundary rows,
        batch over the DP axes;
      * ``cap`` — per-group capture [S+L-1, (n_super,) B, ...]: batch with
        the DP axes, stacked dim over ``stacked_axis`` when divisible;
      * ``step`` — replicated scalar cursor.

    Only the keys present in ``carry_shape`` (plus ``xs``) are returned,
    so the spec tree always matches the carry structure — full and
    streaming carries alike.

    The engine commits the freshly built carry to these specs once at
    pipeline start; every subsequent ``prefill_step`` output inherits the
    placement (the step body re-constrains buf/state internally)."""
    bspec = slot_buf_spec(mesh, n_layers, batch)
    bax = batch_axes(mesh, batch, leaf="pipeline_carry")
    seg_spec = NamedSharding(mesh, P(None, bax, None, None))
    out = {
        "buf": NamedSharding(mesh, bspec if bspec is not None
                             else P(None, None, None, None)),
        "state": decode_state_specs(carry_shape["state"], mesh, batch,
                                    stacked_axis=stacked_axis),
        "step": NamedSharding(mesh, P()),
        "xs": seg_spec,
    }
    if "ys" in carry_shape:
        out["ys"] = seg_spec
    if "win" in carry_shape:
        out["win"] = seg_spec
    if "brow" in carry_shape:
        out["brow"] = NamedSharding(mesh, P(None, bax, None))
    if "cap" in carry_shape:
        def one(path, leaf):
            names = _path_names(path)
            stacked = "pattern" in names
            bdim = 2 if stacked else 1           # [steps, (n_super,) B, ...]
            spec = [None] * len(leaf.shape)
            if len(leaf.shape) > bdim:
                spec[bdim] = bax
            if (stacked and stacked_axis
                    and _div(leaf.shape[1], mesh.shape[stacked_axis])):
                spec[1] = stacked_axis
            return NamedSharding(mesh, P(*spec))
        out["cap"] = jax.tree_util.tree_map_with_path(one, carry_shape["cap"])
    return out


def pool_carry_specs(carry_pool: Any, mesh: Mesh, n_layers: int,
                     batch: int, *,
                     stacked_axis: Optional[str] = None) -> Any:
    """NamedShardings for a POOLED admission carry (DESIGN.md §12): every
    leaf of ``carry_pool`` leads with a pool axis [n_pool, ...] stacking N
    same-shape B=1 admission carries (``core.diagonal.pipeline_step_pool``).

    The pool axis is REPLICATED; within a member the layout is exactly
    ``pipeline_carry_specs`` (model/stage axes still shard). Sharding the
    pool axis over the DP axes is deliberately left on the table: a
    member's state leaves are model-sharded on their last dims, and
    stacking them under a data-sharded leading axis forces XLA's SPMD
    partitioner into "involuntary full rematerialization" reshards at the
    stack/unstack reshapes — observed to MISCOMPILE (≈3e-1 divergence) on
    multi-device CPU. Admissions are B=1 carries, so the DP win would be
    marginal anyway. ``carry_pool`` may be a tree of ShapeDtypeStructs or
    traced values (specs only read shapes), so the pooled stepper can
    build its constraint tree at trace time. ``xs`` is not part of a
    carry pool (read-only, never donated) — only the carry keys are
    returned."""
    member = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), carry_pool)
    base = pipeline_carry_specs(member, mesh, n_layers, batch,
                                stacked_axis=stacked_axis)
    base.pop("xs")
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, P(None, *s.spec)), base)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
