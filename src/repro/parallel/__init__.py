from repro.parallel import sharding
