"""Continuous-batching scheduler: a fixed pool of decode slots fed from a
request queue (DESIGN.md §8).

Each slot is one batch row of a pooled decode state and owns the full
per-request serving state: the ARMT recurrent memory (A, z) / SSM state of
every layer, the current-segment KV cache, and an *in-segment position* —
``state['pos']`` is an int32 [n_slots] vector (``per_slot_pos``), so
requests sit at heterogeneous segment phases inside one jitted step.

The decode loop is a packed ``decode_step`` over all slots followed by a
``jnp.where``-masked ``flush_segment`` for exactly the slots that crossed a
segment boundary this step — one compiled graph, no host branching, no
per-token device->host transfer. Tokens cross to the host once per
``chunk`` steps (a single transfer of the chunk's token block), which is
when finished slots are freed and queued requests admitted.

Admission runs the diagonal prefill (ServeEngine._prefill, including the
fused grouped path when the engine was built with grouped_impl='fused') on
the new request alone, then transplants the resulting B=1 decode state into
a free slot of the pool with ``.at[slot].set`` — other slots keep decoding
across admissions (their rows are untouched).

Slot-state invariants (DESIGN.md §8):
  * a slot row is meaningful iff its host-side `_Slot.active` is True; an
    inactive slot's row is garbage and is fully overwritten at admission
    (every leaf row, pos, and pending token) — nothing is read from it;
  * inactive slots still flow through the packed step (fixed shapes), but
    their `pos` is frozen and the flush mask excludes them, so they never
    flush and their garbage never influences an active row;
  * per-slot independence of the math itself: all decode ops are
    batch-row-local. The one exception is MoE with `dispatch='global'` and
    a tight capacity factor (capacity drops depend on co-batched rows) —
    serve MoE archs with `dispatch='per_row'` or a dropless capacity if
    exact single-request equivalence matters;
  * host mirrors (remaining/active) are advanced from the chunk's emit
    masks only, so host and device views never need a reconciling sync.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Iterable, Iterator, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, flush_segment


@dataclass
class Request:
    """One generation request. prompt: int32 [P] token ids (P >= 1)."""
    req_id: Union[int, str]
    prompt: np.ndarray
    max_new: int


@dataclass
class StreamEvent:
    """One generated token, streamed as soon as its chunk reaches the host."""
    req_id: Union[int, str]
    token: int
    index: int                  # 0-based position within the request's output
    done: bool                  # True on the request's final token


@dataclass
class _Slot:
    req_id: Optional[Union[int, str]] = None
    remaining: int = 0
    index: int = 0
    active: bool = False
    tokens: List[int] = field(default_factory=list)


class ContinuousScheduler:
    """Drives a ServeEngine over many requests with continuous batching."""

    def __init__(self, engine, *, n_slots: int = 4, chunk: int = 8):
        from repro.models import decode_state_init
        assert n_slots >= 1 and chunk >= 1
        self.engine = engine
        self.n_slots = n_slots
        self.chunk = chunk
        cfg = engine.cfg
        dtype = engine.params["embed"].dtype
        self.pool = decode_state_init(
            cfg, n_slots, serve_mode=engine.serve_mode,
            max_len=engine.max_len, dtype=dtype, per_slot_pos=True)
        self.tok = jnp.zeros((n_slots,), jnp.int32)      # pending next input
        self.active = jnp.zeros((n_slots,), bool)
        self.remaining = jnp.zeros((n_slots,), jnp.int32)
        self.slots = [_Slot() for _ in range(n_slots)]
        self.free: deque = deque(range(n_slots))
        # the jitted step/admit functions are cached on the engine (keyed by
        # chunk) so repeated serve() calls — and schedulers with different
        # slot counts, which only differ in traced shapes — reuse compiles
        self._chunk_fn, self._admit_fn = scheduler_fns(engine, chunk)

    # ------------------------------------------------------------------
    # Host-side driver
    # ------------------------------------------------------------------

    def _admit(self, req: Request) -> None:
        assert req.max_new >= 1, f"{req.req_id}: max_new must be >= 1"
        prompt = np.asarray(req.prompt, np.int32)
        assert prompt.ndim == 1 and prompt.shape[0] >= 1, req.req_id
        if (self.engine.serve_mode == "cache"
                and prompt.shape[0] + req.max_new > self.engine.max_len):
            raise ValueError(
                f"{req.req_id}: prompt+max_new exceeds max_len "
                f"{self.engine.max_len} of the KV cache")
        slot = self.free.popleft()
        # diagonal prefill of the new request alone; other slots' rows are
        # untouched and keep decoding across this call
        logits, one_state, pos = self.engine._prefill(prompt[None])
        first_tok = jnp.argmax(logits[0], axis=-1).astype(jnp.int32)
        self.pool, self.tok, self.active, self.remaining = self._admit_fn(
            self.pool, self.tok, self.active, self.remaining,
            jnp.int32(slot), one_state, first_tok,
            jnp.int32(pos), jnp.int32(req.max_new))
        s = self.slots[slot]
        s.req_id, s.remaining, s.index, s.active, s.tokens = (
            req.req_id, req.max_new, 0, True, [])

    def run(self, requests: Iterable[Request]) -> Iterator[StreamEvent]:
        """Generator: admits requests as slots free up and yields one
        StreamEvent per generated token (chunk-granular latency)."""
        queue = deque(requests)
        while True:
            while self.free and queue:
                self._admit(queue.popleft())
            if not any(s.active for s in self.slots):
                if not queue:
                    return
                continue
            (self.pool, self.tok, self.active, self.remaining,
             toks, masks) = self._chunk_fn(
                self.engine.params, self.pool, self.tok,
                self.active, self.remaining)
            # the single device->host transfer for these `chunk` tokens
            toks_np = np.asarray(toks)
            masks_np = np.asarray(masks)
            for t in range(self.chunk):
                for b, s in enumerate(self.slots):
                    if not masks_np[t, b] or not s.active:
                        continue
                    s.remaining -= 1
                    done = s.remaining == 0
                    tok = int(toks_np[t, b])
                    s.tokens.append(tok)
                    yield StreamEvent(s.req_id, tok, s.index, done)
                    s.index += 1
                    if done:
                        s.active = False
                        self.free.append(b)



def scheduler_fns(engine, chunk: int):
    """Build (or fetch from the engine's cache) the jitted packed-chunk and
    admission functions shared by every scheduler on this engine."""
    cache = engine._sched_fns
    if chunk in cache:
        return cache[chunk]
    cfg = engine.cfg
    serve_mode = engine.serve_mode
    seg_len = engine.seg_len
    armt_on = serve_mode == "armt" and cfg.armt is not None
    donate_ok = jax.default_backend() != "cpu"

    def chunk_fn(params, state, tok, active, remaining):
        def body(carry, _):
            state, tok, active, remaining = carry
            emit, emit_mask = tok, active
            logits, new_state = decode_step(params, cfg, state, tok,
                                            serve_mode=serve_mode)
            # freeze inactive slots' positions: they never hit a segment
            # boundary, so garbage rows never trigger (or mask into) a
            # flush, and their cache writes stay at one frozen offset
            new_state["pos"] = jnp.where(active, new_state["pos"],
                                         state["pos"])
            if armt_on:
                boundary = active & (new_state["pos"] >= seg_len)
                new_state = jax.lax.cond(
                    boundary.any(),
                    lambda s: flush_segment(params, cfg, s,
                                            slot_mask=boundary),
                    lambda s: s, new_state)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            nxt = jnp.where(active, nxt, tok)
            remaining = remaining - emit_mask.astype(jnp.int32)
            active = active & (remaining > 0)
            return (new_state, nxt, active, remaining), (emit, emit_mask)

        (state, tok, active, remaining), (toks, masks) = jax.lax.scan(
            body, (state, tok, active, remaining), None, length=chunk)
        return state, tok, active, remaining, toks, masks

    def admit_fn(pool, tok, active, remaining, slot, one_state,
                 first_tok, pos_val, n_new):
        prelude = jax.tree_util.tree_map(
            lambda pl, ol: pl.at[slot].set(ol[0].astype(pl.dtype)),
            tuple(pool["prelude"]), tuple(one_state["prelude"]))
        pattern = jax.tree_util.tree_map(
            lambda pl, ol: pl.at[:, slot].set(ol[:, 0].astype(pl.dtype)),
            tuple(pool["pattern"]), tuple(one_state["pattern"]))
        new_pool = {"prelude": prelude, "pattern": pattern,
                    "pos": pool["pos"].at[slot].set(pos_val)}
        return (new_pool,
                tok.at[slot].set(first_tok),
                active.at[slot].set(True),
                remaining.at[slot].set(n_new))

    fns = (jax.jit(chunk_fn, donate_argnums=(1, 2, 3, 4) if donate_ok else ()),
           jax.jit(admit_fn, donate_argnums=(0, 1, 2, 3) if donate_ok else ()))
    cache[chunk] = fns
    return fns
