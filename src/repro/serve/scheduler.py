"""Continuous-batching scheduler: a fixed pool of decode slots fed from a
request queue (DESIGN.md §8).

Each slot is one batch row of a pooled decode state and owns the full
per-request serving state: the ARMT recurrent memory (A, z) / SSM state of
every layer, the current-segment KV cache, and an *in-segment position* —
``state['pos']`` is an int32 [n_slots] vector (``per_slot_pos``), so
requests sit at heterogeneous segment phases inside one jitted step.

The decode loop is a packed ``decode_step`` over all slots followed by a
``jnp.where``-masked ``flush_segment`` for exactly the slots that crossed a
segment boundary this step — one compiled graph, no host branching, no
per-token device->host transfer. Tokens cross to the host once per
``chunk`` steps (a single transfer of the chunk's token block), which is
when finished slots are freed and queued requests admitted.

Admission is *interleaved* by default (DESIGN.md §11): the new request's
prefill runs as a resumable diagonal pipeline (``ServeEngine.start_prefill``)
that advances ``prefill_groups_per_chunk`` anti-diagonal groups between
decode chunks, so a 128k-token admission no longer freezes every decoding
slot for its whole prompt — the last head-of-line block the diagonal
schedule left in the serving stack. ``prefill_groups_per_chunk=0`` restores
the legacy blocking admission (one ``ServeEngine._prefill`` call); with
``fused_admission=True`` the admitting requests' segment-cells ride the
same jitted launch as the decode cells (one combined program per chunk
interval, ``fused_fns`` / ``fused_pool_fns``). Either way the finished B=1
state is transplanted into a free slot of the pool with ``.at[slot].set``
— other slots keep decoding across admissions (their rows are untouched),
and the admission itself is token-identical (greedy) to the blocking path
(tests/test_serve_interleave.py). With a prefix cache on the engine,
admission prefills only the uncached tail segments; with a session store,
a request carrying a known ``session_id`` transplants the stored
conversation state and feeds only the new turn (O(new turn) admission).

Up to ``max_concurrent_admissions`` admissions are in flight at once
(DESIGN.md §12; default None = bounded only by free slots): each holds a
reserved slot and a suspended carry, and every scheduler round is one
*global* (request, segment, layer) work set — k ready diagonal groups from
EACH in-flight admission plus the packed decode chunk. Same-signature
carries batch into one pooled stepper launch (engine.AdmissionPool), and
with ``fused_admission`` the whole round — decode chunk plus every pooled
bucket — is ONE jitted program. Fairness is round-robin by default (every
admission advances k groups per round; slots assigned FIFO at start, so no
admission starves); ``admission_fairness='oldest_first'`` is the
head-of-line reference policy. Queue wait (``t_admit - t_submit``) and the
concurrent-admission count are recorded per request on its StreamEvents.
When no decode slot is active, pending admissions drain in a tight loop
(no per-round scheduling-pass overhead) until a transplant reactivates
decode or a new request could start.

Requests are pulled from the ``requests`` iterable *lazily between
chunks* — a live/streaming source is served as it arrives instead of being
drained before the decode loop starts, and each request's ``t_submit`` is
taken at pull time. With ``max_queue=None`` (the default, the pull model)
backpressure is simply not pulling: nothing is read from the source until
the scheduler can start it. A live source may ``yield None`` to say "no
request ready yet" — the scheduler keeps decoding and polls again at the
next chunk boundary rather than blocking in ``next()``. Setting ``max_queue`` selects the push model:
the source is drained into a bounded backlog and overflow is rejected with
a structured ``queue_full`` event (slots count as capacity, as before).

Rejections are *structured*: invalid requests, a full queue, and evicted
sessions yield ``RequestError`` events on the stream — ``run`` never raises
mid-serve for a bad request, so one malformed request cannot kill the other
slots' in-flight generations.

On a mesh-native engine (``ServeEngine(mesh=...)``, DESIGN.md §10) the pool
and its per-slot control vectors are committed to the engine's decode-state
shardings at construction — slot rows over the 'data' axes, heads/d_model
over 'model' — and the packed chunk / admission / extract jits run as GSPMD
programs over the mesh; the host driver below is unchanged.

Slot-state invariants (DESIGN.md §8):
  * a slot row is meaningful iff its host-side `_Slot.active` is True; an
    inactive slot's row is garbage and is fully overwritten at admission
    (every leaf row, pos, and pending token) — nothing is read from it;
  * inactive slots still flow through the packed step (fixed shapes), but
    every leaf of their state is frozen by a ``jnp.where`` row-merge
    (mask_decode_state) and the flush mask excludes them — so a finished
    request's row is bit-exactly its end-of-generation state at the chunk
    boundary, which is what the session store persists (§9);
  * per-slot independence of the math itself: all decode ops are
    batch-row-local. The one exception is MoE with `dispatch='global'` and
    a tight capacity factor (capacity drops depend on co-batched rows) —
    serve MoE archs with `dispatch='per_row'` or a dropless capacity if
    exact single-request equivalence matters;
  * host mirrors (remaining/active) are advanced from the chunk's emit
    masks only, so host and device views never need a reconciling sync.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Iterable, Iterator, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, flush_segment, mask_decode_state


@dataclass
class Request:
    """One generation request. prompt: int32 [P] token ids (P >= 1).

    session_id: resume/persist the conversation in the engine's session
    store — the prompt is then this turn's new tokens only."""
    req_id: Union[int, str]
    prompt: np.ndarray
    max_new: int
    session_id: Optional[str] = None


@dataclass
class StreamEvent:
    """One generated token, streamed as soon as its chunk reaches the host."""
    req_id: Union[int, str]
    token: int
    index: int                  # 0-based position within the request's output
    done: bool                  # True on the request's final token
    # host-clock serving metrics, chunk-granular by design: set on the
    # request's first event (ttft_s) and final event (ttft_s + tok_s).
    # ttft_s counts from submission (pull time — queue wait included, which
    # is the latency a caller feels); tok_s counts from *admission* (queue
    # wait excluded, prefill included), so it measures this request's
    # service rate, not the queue depth. GenerationResult.tok_s is
    # decode-only. t_emit is the host clock at the chunk boundary that
    # surfaced this token — inter-token-latency and admission-stall
    # aggregation (benchmarks/bench_serve.py) reads it off the stream.
    ttft_s: Optional[float] = None
    tok_s: Optional[float] = None
    t_emit: Optional[float] = None
    # queue-wait breakdown (DESIGN.md §12), set on first and final events:
    # t_admit - t_submit is the time the request sat queued before its
    # admission started (the component concurrent admissions attack —
    # ttft_s = queue_wait_s + service time), and concurrent_admissions is
    # how many admissions were in flight when this one started (its own
    # included; 1 = it had the admission machinery to itself).
    queue_wait_s: Optional[float] = None
    concurrent_admissions: Optional[int] = None


@dataclass
class RequestError:
    """Structured rejection streamed in-band instead of raising out of the
    serve iterator mid-flight. code: 'invalid_request' | 'queue_full' |
    'session_evicted'."""
    req_id: Union[int, str]
    code: str
    message: str


@dataclass
class _Slot:
    req_id: Optional[Union[int, str]] = None
    remaining: int = 0
    index: int = 0
    active: bool = False
    tokens: List[int] = field(default_factory=list)
    session_id: Optional[str] = None
    prompt: Optional[np.ndarray] = None
    history: Optional[np.ndarray] = None    # prior session turns (consumed)
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: Optional[float] = None
    n_concurrent: int = 1        # admissions in flight when this one started
    # host mirror of the device-side in-segment position (DESIGN.md §13):
    # seeded with the admission's pos, advanced one per emitted token, reset
    # at seg_len — exactly the arithmetic decode_step/flush_segment run on
    # device, so in-graph segment flushes are visible on the trace timeline
    # without any device readback
    pos: int = 0


@dataclass
class _Admission:
    """Host record of one in-flight interleaved admission: the suspended
    prefill pipeline plus the slot it has reserved and the metadata the
    transplant needs on completion. The scheduler keeps a FIFO list of
    these (up to ``max_concurrent_admissions``), mirrored by the engine's
    AdmissionPool which batches their device work (DESIGN.md §12)."""
    req: Request
    slot: int
    pipe: object                 # serve.engine.PrefillPipeline
    entry: object                # SessionEntry or None
    prompt: np.ndarray
    t_submit: float
    t_admit: float
    n_concurrent: int = 1


class ContinuousScheduler:
    """Drives a ServeEngine over many requests with continuous batching."""

    def __init__(self, engine, *, n_slots: int = 4, chunk: int = 8,
                 max_queue: Optional[int] = None,
                 prefill_groups_per_chunk: int = 4,
                 fused_admission: bool = False,
                 max_concurrent_admissions: Optional[int] = None,
                 admission_fairness: str = "round_robin",
                 admission_byte_budget: Optional[int] = None):
        from repro.models import decode_state_init
        from repro.serve.engine import AdmissionPool
        assert n_slots >= 1 and chunk >= 1
        assert prefill_groups_per_chunk >= -1
        assert (max_concurrent_admissions is None
                or max_concurrent_admissions >= 1), max_concurrent_admissions
        assert admission_fairness in ("round_robin", "oldest_first"), \
            admission_fairness
        self.engine = engine
        self.n_slots = n_slots
        self.chunk = chunk
        self.max_queue = max_queue
        # interleaved admission (DESIGN.md §11): diagonal groups each
        # admitting request's pipeline advances per decode chunk; 0 =
        # legacy blocking admission (one eager _prefill call); -1 = one
        # whole diagonal stage per chunk (blocking semantics for
        # single-stage prompts, but through the jitted stepper — the
        # bench's fair blocking baseline)
        self.prefill_groups_per_chunk = prefill_groups_per_chunk
        self.fused_admission = fused_admission
        # pooled concurrent admissions (DESIGN.md §12): up to this many
        # interleaved admissions in flight at once, each holding a reserved
        # slot; None bounds the pool only by free slots, 1 restores the
        # PR 5 single-admission behavior (and its exact compiled programs)
        self.max_concurrent_admissions = max_concurrent_admissions
        self.admission_fairness = admission_fairness
        # overflow-aware admission (DESIGN.md §15): prompts whose full-ys
        # prefill would exceed this many activation bytes go through the
        # streaming carry with byte-bounded stages; None disables the check
        assert admission_byte_budget is None or admission_byte_budget > 0, \
            admission_byte_budget
        self.admission_byte_budget = admission_byte_budget
        self._adms: List[_Admission] = []            # FIFO
        self._pool_adm = AdmissionPool(engine)
        # idle-drain observability: rounds run inside the tight loop that
        # drains pending admissions while no decode slot is active
        self.idle_drain_rounds = 0
        # (t_start, t_end) of every completed admission — the bench reads
        # these to compute admission_stall (max decode gap overlapping an
        # admission window)
        self.admission_windows: List[tuple] = []
        cfg = engine.cfg
        dtype = engine.params["embed"].dtype
        self.pool = decode_state_init(
            cfg, n_slots, serve_mode=engine.serve_mode,
            max_len=engine.max_len, dtype=dtype, per_slot_pos=True)
        self.tok = jnp.zeros((n_slots,), jnp.int32)      # pending next input
        self.active = jnp.zeros((n_slots,), bool)
        self.remaining = jnp.zeros((n_slots,), jnp.int32)
        if engine.mesh is not None:
            # mesh-native pool (DESIGN.md §10): slot rows shard over the DP
            # axes, heads/d_model over 'model'; the per-slot control vectors
            # (pending token / active / remaining) shard with the slots, so
            # the packed chunk step is one GSPMD program over the mesh
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.parallel import sharding as shd
            self.pool = jax.device_put(
                self.pool, engine.state_sharding(n_slots, per_slot_pos=True))
            vec = NamedSharding(
                engine.mesh,
                P(shd.batch_axes(engine.mesh, n_slots, leaf="slot_vec")))
            self.tok = jax.device_put(self.tok, vec)
            self.active = jax.device_put(self.active, vec)
            self.remaining = jax.device_put(self.remaining, vec)
        self.slots = [_Slot() for _ in range(n_slots)]
        self._armt_flush = (engine.serve_mode == "armt"
                            and engine.cfg.armt is not None)
        self.free: deque = deque(range(n_slots))
        # the jitted step/admit/extract functions are cached on the engine
        # (keyed by chunk) so repeated serve() calls — and schedulers with
        # different slot counts, which only differ in traced shapes — reuse
        # compiles
        self._chunk_fn, self._admit_fn, self._extract_fn = \
            scheduler_fns(engine, chunk)

    @property
    def tel(self):
        """The engine's telemetry bundle (DESIGN.md §13) — resolved
        dynamically so a caller swapping ``engine.telemetry`` between
        serve() calls (the bench does) is picked up without rebuilding the
        scheduler."""
        return self.engine.telemetry

    # ------------------------------------------------------------------
    # Host-side driver
    # ------------------------------------------------------------------

    def _validate(self, req: Request) -> Optional[RequestError]:
        prompt = np.asarray(req.prompt)
        if req.max_new < 1:
            return RequestError(req.req_id, "invalid_request",
                                f"max_new must be >= 1, got {req.max_new}")
        if prompt.ndim != 1 or prompt.shape[0] < 1:
            return RequestError(req.req_id, "invalid_request",
                                f"prompt must be a [P>=1] id vector, got "
                                f"shape {prompt.shape}")
        if (self.engine.serve_mode == "cache"
                and prompt.shape[0] + req.max_new > self.engine.max_len):
            return RequestError(
                req.req_id, "invalid_request",
                f"prompt+max_new exceeds max_len {self.engine.max_len} of "
                "the KV cache")
        if (req.session_id is not None
                and self.engine.session_store is None):
            return RequestError(req.req_id, "invalid_request",
                                "request carries a session_id but the "
                                "engine has no session_store")
        return None

    def _admission_plan(self, prompt_len: int):
        """Byte-budget admission decision (DESIGN.md §15): returns
        ``(stream, max_stage_segments)`` for a prompt of ``prompt_len``
        tokens. Prompts whose full-``ys`` prefill fits the budget keep the
        default path bit for bit; oversized prompts stream (rolling
        win/brow carry) with stages capped so even the per-stage ``xs``
        fits, and the decision is counted + the compiled stepper's
        temp/peak bytes published as gauges. Host arithmetic only — no
        device sync on the admit path."""
        budget = self.admission_byte_budget
        if budget is None:
            return False, None
        S = prompt_len // self.engine.seg_len
        if S < 2 or self.engine.prefill_activation_bytes(
                S, stream=False) <= budget:
            return False, None
        max_g = S
        while max_g > 1 and self.engine.prefill_activation_bytes(
                max_g, stream=True) > budget:
            max_g //= 2
        self.tel.inc("overflow_admissions_total")
        self.tel.set_gauge("admission_stage_cap_segments", max_g)
        k = self.prefill_groups_per_chunk
        self.engine.prefill_memory_stats(
            min(max_g, S), stream=True,
            n_groups=(k if k and k > 0 else 4))
        return True, (max_g if max_g < S else None)

    def _admit(self, req: Request, t_submit: float) -> Optional[RequestError]:
        """Prefill (or session-resume) the request alone and transplant it
        into a free slot; other slots keep decoding across this call.
        Returns a RequestError instead of admitting when rejected."""
        err = self._validate(req)
        if err is not None:
            return err
        t_admit = time.perf_counter()
        prompt = np.asarray(req.prompt, np.int32)
        entry = None
        if req.session_id is not None:
            from repro.serve.state_store import SessionEvicted
            try:
                entry = self.engine.session_store.get(req.session_id)
            except SessionEvicted as e:
                return RequestError(req.req_id, "session_evicted", str(e))
        slot = self.free.popleft()
        if entry is not None:
            # O(new turn) resume: transplant the stored conversation state
            # and feed only pending + this turn's tokens. _place_state is
            # the scatter-on-restore boundary: blobs are mesh-shape-agnostic
            # host arrays when they were captured sharded — commit them to
            # this engine's shardings (a device_put, not a host round-trip,
            # when they are already device-resident)
            with self.tel.span("session_restore", "session",
                               lane=str(req.req_id), session=req.session_id):
                restored = self.engine._place_state(
                    {"prelude": entry.state["prelude"],
                     "pattern": entry.state["pattern"]}, 1)
                dstate = {**restored,
                          "pos": jnp.asarray(entry.pos, jnp.int32)}
                toks_in = np.concatenate([entry.pending, prompt])
                logits, one_state, pos = self.engine._chunk(
                    dstate, jnp.asarray(toks_in[None]), entry.pos)
        else:
            stream, max_g = self._admission_plan(prompt.shape[0])
            if stream:
                # oversized prompt under the byte budget: drain a streaming
                # resumable pipeline synchronously — blocking semantics,
                # bounded memory (the full-ys _prefill would hold the whole
                # O(S) activation set at once)
                pipe = self.engine.start_prefill(
                    prompt[None], groups_per_call=None, stream=True,
                    max_stage_segments=max_g)
                while not pipe.advance():
                    pass
                logits, one_state, pos, _cached = pipe.result()
            else:
                # diagonal prefill of the new request alone (longest-prefix
                # cache hit inside _prefill when the engine carries one)
                logits, one_state, pos, _cached = self.engine._prefill(
                    prompt[None])
        self._install(slot, req, entry, prompt, logits, one_state, pos,
                      t_submit, t_admit, n_concurrent=1)
        return None

    def _install(self, slot: int, req: Request, entry, prompt: np.ndarray,
                 logits, one_state, pos: int, t_submit: float,
                 t_admit: float, n_concurrent: int = 1) -> None:
        """Transplant a finished admission into its slot — the single
        completion path shared by blocking (_admit) and interleaved
        (_finish_admission) admission, so the two modes cannot drift
        field-for-field (the token-identity invariant depends on it)."""
        first_tok = jnp.argmax(logits[0], axis=-1).astype(jnp.int32)
        with self.tel.span("transplant", "transplant",
                           lane=str(req.req_id), slot=slot):
            self.pool, self.tok, self.active, self.remaining = self._admit_fn(
                self.pool, self.tok, self.active, self.remaining,
                jnp.int32(slot), one_state, first_tok,
                jnp.int32(pos), jnp.int32(req.max_new))
        s = self.slots[slot]
        s.req_id, s.remaining, s.index, s.active, s.tokens = (
            req.req_id, req.max_new, 0, True, [])
        s.session_id, s.prompt = req.session_id, prompt
        s.history = (entry.tokens if entry is not None
                     else np.empty(0, np.int32))
        s.t_submit, s.t_admit, s.t_first = t_submit, t_admit, None
        s.n_concurrent = n_concurrent
        s.pos = int(pos)
        t_end = time.perf_counter()
        self.admission_windows.append((t_admit, t_end))
        # retroactive span covering the whole admission window (start ->
        # transplant landed), on the request's own lane — the trace-side
        # twin of the admission_windows record the bench reads
        self.tel.add_span("admission", "admission", t_admit, t_end,
                          lane=str(req.req_id), slot=slot,
                          queue_wait_s=t_admit - t_submit,
                          concurrent=n_concurrent)
        self.tel.inc("admissions_total")
        self.tel.observe("queue_wait_s", t_admit - t_submit)
        self.tel.observe("admission_window_s", t_end - t_admit)

    def _interleave(self) -> bool:
        """Interleaved admission needs the resumable pipeline's diagonal
        stepper for segment stages; tail-only admissions ('cache' mode) are
        schedule-agnostic. Everything else falls back to blocking."""
        if self.prefill_groups_per_chunk == 0:
            return False
        eng = self.engine
        return eng.schedule == "diagonal" or eng.serve_mode != "armt"

    def _can_admit(self) -> bool:
        """Room for another admission to START (a free slot is checked by
        the caller). Blocking admissions are synchronous, so ``_adms`` is
        empty and they are never capped; interleaved admissions respect
        ``max_concurrent_admissions``."""
        return (self.max_concurrent_admissions is None
                or len(self._adms) < self.max_concurrent_admissions)

    def _start(self, req: Request, t_submit: float) -> Optional[RequestError]:
        """Begin serving ``req``: the full blocking admission when
        interleaving is off/unavailable, else reserve a slot and suspendably
        prefill via the engine's pipeline — the new member joins the
        admission pool and advances every fairness round (``run``).
        Returns a RequestError instead of starting when rejected."""
        if not self._interleave():
            return self._admit(req, t_submit)
        err = self._validate(req)
        if err is not None:
            return err
        t_admit = time.perf_counter()
        prompt = np.asarray(req.prompt, np.int32)
        entry = None
        if req.session_id is not None:
            from repro.serve.state_store import SessionEvicted
            try:
                entry = self.engine.session_store.get(req.session_id)
            except SessionEvicted as e:
                return RequestError(req.req_id, "session_evicted", str(e))
        slot = self.free.popleft()
        k = self.prefill_groups_per_chunk
        stream, max_g = (self._admission_plan(prompt.shape[0])
                         if entry is None else (False, None))
        pipe = self.engine.start_prefill(
            prompt[None], groups_per_call=(None if k < 0 else k),
            session_entry=entry, stream=stream, max_stage_segments=max_g)
        self._adms.append(_Admission(
            req=req, slot=slot, pipe=pipe, entry=entry, prompt=prompt,
            t_submit=t_submit, t_admit=t_admit,
            n_concurrent=len(self._adms) + 1))
        self._pool_adm.add(pipe)
        return None

    def _finish_admissions(self, done_pipes) -> None:
        """Pipelines that completed this round: transplant each B=1 state
        into its reserved slot, FIFO (identical to blocking admission from
        here)."""
        for pipe in done_pipes:
            adm = next(a for a in self._adms if a.pipe is pipe)
            logits, one_state, pos, _cached = pipe.result()
            self._install(adm.slot, adm.req, adm.entry, adm.prompt, logits,
                          one_state, pos, adm.t_submit, adm.t_admit,
                          n_concurrent=adm.n_concurrent)
            self._adms.remove(adm)

    def _fused_round(self):
        """The global-grid launch (DESIGN.md §12): ONE jitted program runs
        the packed decode chunk over every slot plus k diagonal groups from
        every pooled admission bucket. Returns ``(toks, masks, advanced)``
        — the chunk's outputs and the ids of pipes the launch advanced
        (tail-piece members are not in any bucket and advance individually
        afterwards)."""
        buckets = self._pool_adm.diag_buckets()
        if not buckets:
            return None, None, frozenset()
        order = sorted(buckets.keys())        # deterministic compile key
        sigs, xs_b, carry_b, groups = [], [], [], []
        for sig in order:
            g_segs, capture, stream, k = sig
            group = buckets[sig]
            n_pool, xs_t, carry_t = self.engine.pool_pack(g_segs, group)
            sigs.append((g_segs, capture, stream, k, n_pool))
            xs_b.append(xs_t)
            carry_b.append(carry_t)
            groups.append(group)
        ffn = fused_pool_fns(self.engine, self.chunk, tuple(sigs))
        with self.engine._mesh_ctx():
            (self.pool, self.tok, self.active, self.remaining, toks, masks,
             out_b) = ffn(self.engine.params, self.pool, self.tok,
                          self.active, self.remaining, tuple(xs_b),
                          tuple(carry_b))
        advanced = set()
        for group, outs in zip(groups, out_b):
            for (pipe, _, _), c in zip(group, outs):
                pipe.apply_diag_result(c)
                advanced.add(id(pipe))
        return toks, masks, frozenset(advanced)

    def _advance_admissions(self):
        """Span-wrapped fairness round — every pooled admission round
        (interleaved AND idle-drain) shows up on the trace timeline with
        its pool size and launch mode."""
        with self.tel.span("admission_round", "admission",
                           n_adms=len(self._adms),
                           fused=self.fused_admission):
            return self._advance_admissions_inner()

    def _advance_admissions_inner(self):
        """One fairness round over the in-flight admissions: every member
        advances one bounded unit — its k diagonal groups (same-signature
        members batched into one pooled launch) or one tail piece. With
        ``fused_admission`` and active decode slots, the decode chunk and
        every bucket's pooled groups run as ONE jitted program
        (``fused_pool_fns``); the single-admission case keeps PR 5's
        ``fused_fns`` path (same compiled programs). Completed admissions
        transplant FIFO into their reserved slots. Returns ``(toks,
        masks)`` when the fused launch ran the decode chunk, else
        ``(None, None)``."""
        toks = masks = None
        run_fused = self.fused_admission and any(s.active for s in self.slots)
        if self.admission_fairness == "oldest_first" and len(self._adms) > 1:
            done_pipes = self._pool_adm.advance_oldest()
        elif len(self._adms) == 1:
            # PR 5 single-carry path bit for bit (and its compiled programs)
            pipe = self._adms[0].pipe
            fused = pipe.active_diag() if run_fused else None
            if fused is not None:
                g, capture, xs, carry = fused
                ffn = fused_fns(self.engine, self.chunk, g, capture,
                                pipe._groups_per_advance())
                with self.engine._mesh_ctx():
                    (self.pool, self.tok, self.active, self.remaining,
                     toks, masks, carry) = ffn(
                        self.engine.params, self.pool, self.tok,
                        self.active, self.remaining, xs, carry)
                done = pipe.apply_diag_result(carry)
            else:
                done = pipe.advance()
            done_pipes = [pipe] if done else []
            if done:
                self._pool_adm.members.remove(pipe)
        else:
            advanced = frozenset()
            if run_fused:
                toks, masks, advanced = self._fused_round()
            done_pipes = self._pool_adm.advance_round(
                already_advanced=advanced)
        self._finish_admissions(done_pipes)
        return toks, masks

    def _persist_session(self, b: int) -> None:
        """End of generation for slot b: lift its row out of the pool
        (device-side gather at the chunk boundary — the packed chunk froze
        the row bit-exactly at its end-of-generation state) and persist it.
        The scheduler's step consumes every emitted token (unlike
        generate's loop), so nothing is pending on resume."""
        s = self.slots[b]
        with self.tel.span("session_persist", "session",
                           lane=str(s.req_id), session=s.session_id):
            row, pos, _pend = self._extract_fn(self.pool, self.tok,
                                               jnp.int32(b))
            history = np.concatenate(
                [s.history, s.prompt,
                 np.asarray(s.tokens, np.int32)]).astype(np.int32)
            self.engine.session_store.put(
                s.session_id, state=row, pos=int(np.asarray(pos)),
                pending=np.empty(0, np.int32), tokens=history)

    def _drain_chunk(self, toks, masks) -> Iterator[StreamEvent]:
        """Cross one chunk's token block to the host and stream its events
        (the single device->host transfer for these ``chunk`` steps).

        This is the telemetry piggyback point (DESIGN.md §13): the
        ``decode_chunk`` span brackets exactly the two ``np.asarray``
        transfers that already existed (so its duration is the
        device-sync + copy wall time), per-request emit stamps and
        per-chunk occupancy metrics are computed from the host copies, and
        nothing else touches the device — the one-transfer-per-chunk
        invariant is regression-tested with telemetry enabled."""
        tel = self.tel
        n_active = sum(1 for s in self.slots if s.active)
        with tel.span("decode_chunk", "decode", steps=self.chunk,
                      active_slots=n_active):
            toks_np = np.asarray(toks)
            masks_np = np.asarray(masks)
        now = time.perf_counter()
        if tel.trace is not None:
            for b, s in enumerate(self.slots):
                if s.active:
                    n = int(masks_np[:, b].sum())
                    if n:
                        tel.emit(s.req_id, now, n)
        tel.observe("chunk_active_slots", n_active)
        tel.observe("chunk_admissions_in_flight", len(self._adms))
        tel.set_gauge("pool_occupancy", self.n_slots - len(self.free))
        tel.sample_device_memory()
        for t in range(self.chunk):
            for b, s in enumerate(self.slots):
                if not masks_np[t, b] or not s.active:
                    continue
                s.remaining -= 1
                done = s.remaining == 0
                tok = int(toks_np[t, b])
                s.tokens.append(tok)
                if self._armt_flush:
                    # host pos mirror: the emitted token is the step's input,
                    # so it advanced pos by one; >= seg_len means the jitted
                    # chunk flushed this slot's segment at that step
                    s.pos += 1
                    if s.pos >= self.engine.seg_len:
                        s.pos = 0
                        tel.instant("segment_flush", "flush", t=now,
                                    lane=str(s.req_id))
                        tel.inc("decode_flushes_total")
                first = s.t_first is None
                if first:
                    s.t_first = now
                ev = StreamEvent(s.req_id, tok, s.index, done, t_emit=now)
                if first or done:
                    ev.queue_wait_s = s.t_admit - s.t_submit
                    ev.concurrent_admissions = s.n_concurrent
                if first:
                    ev.ttft_s = now - s.t_submit
                if done:
                    ev.ttft_s = s.t_first - s.t_submit
                    ev.tok_s = (s.index + 1) / max(now - s.t_admit,
                                                   1e-9)
                yield ev
                s.index += 1
                if done:
                    s.active = False
                    if (s.session_id is not None
                            and self.engine.session_store is not None):
                        self._persist_session(b)
                    self.free.append(b)

    def run(self, requests: Iterable[Request]) -> Iterator[
            Union[StreamEvent, RequestError]]:
        """Generator: pulls requests lazily, admits as slots free up
        (interleaving the admitting prefill with decode chunks unless
        ``prefill_groups_per_chunk=0``), and yields one StreamEvent per
        generated token (chunk-granular latency) plus RequestError events
        for rejected requests.

        Live sources: the iterator is only pulled when the scheduler can
        start the request, but ``next()`` on a plain iterator is a
        *blocking* call — a source with nothing ready would stall the
        active streams. A live source should therefore ``yield None`` when
        no request is ready yet: the scheduler stops pulling for that
        round, keeps decoding, and polls again at the next chunk boundary
        (finite lists/generators that always have a request ready are
        unaffected)."""
        it = iter(requests)
        exhausted = False

        def pull() -> Optional[Request]:
            # returns None when the source is exhausted OR yielded None
            # ("nothing ready yet") — either way the caller stops pulling
            # this round; `exhausted` tells the two cases apart at
            # termination time
            nonlocal exhausted
            if exhausted:
                return None
            try:
                return next(it)
            except StopIteration:
                exhausted = True
                return None

        queue: deque = deque()           # (request, t_submit-at-pull)
        while True:
            # ---- start work: backlog first, then pull from the source ----
            while self.free and queue and self._can_admit():
                req, t_sub = queue.popleft()
                err = self._start(req, t_sub)
                if err is not None:
                    yield err
            while not exhausted:
                can_start = (bool(self.free) and not queue
                             and self._can_admit())
                if not can_start and self.max_queue is None:
                    # pull model: backpressure by not pulling — nothing is
                    # read from a live source until we can actually start it
                    break
                if (not can_start and self.max_queue is not None
                        and len(queue) >= self.max_queue + len(self.free)):
                    # push model at capacity: drain + structured rejection.
                    # Free slots count as extra queue capacity — a slot left
                    # idle only because the admission pool is at its
                    # concurrency cap will serve its queued request as soon
                    # as a pooled admission lands
                    req = pull()
                    if req is None:
                        break
                    yield RequestError(
                        req.req_id, "queue_full",
                        f"all {self.n_slots} slots busy or spoken for and "
                        f"queue limit {self.max_queue} reached")
                    continue
                req = pull()
                if req is None:
                    break
                t_sub = time.perf_counter()
                if can_start:
                    err = self._start(req, t_sub)
                    if err is not None:
                        yield err
                else:
                    queue.append((req, t_sub))

            # ---- one fairness round over the in-flight admissions ----
            toks = masks = None
            if self._adms:
                toks, masks = self._advance_admissions()

            # ---- decode chunk (unless the fused launch already ran it) ----
            if toks is None and any(s.active for s in self.slots):
                (self.pool, self.tok, self.active, self.remaining,
                 toks, masks) = self._chunk_fn(
                    self.engine.params, self.pool, self.tok,
                    self.active, self.remaining)
            if toks is not None:
                self.tel.observe("chunk_queue_depth", len(queue))
                yield from self._drain_chunk(toks, masks)
            elif self._adms:
                # idle-drain: no decode slot is active, so there is no
                # chunk to interleave against — drain the pending
                # admissions in a tight loop instead of one k-group round
                # per full scheduling pass. Break out as soon as a
                # transplant lands (decode can resume) or a new request
                # could start (the pull loop must run — a free slot plus
                # pool headroom while the source may still have requests).
                while (self._adms
                       and not any(s.active for s in self.slots)
                       and not (self.free and self._can_admit()
                                and (queue or not exhausted))):
                    with self.tel.span("idle_drain_round", "idle",
                                       pending=len(self._adms)):
                        self._advance_admissions()
                    self.idle_drain_rounds += 1
            else:
                if not queue and exhausted:
                    return
                if not queue:
                    # fully idle on a live source that yielded None
                    # ("nothing ready yet"): back off briefly instead of
                    # spinning on next()
                    time.sleep(1e-3)
                # nothing active, nothing admitting: loop back to pull/admit



def _chunk_body_factory(cfg, serve_mode: str, seg_len: int, chunk: int):
    """The packed decode-chunk body as a pure (un-jitted) function —
    ``scheduler_fns`` jits it standalone; ``fused_fns`` composes it with
    the admission pipeline's stepper inside one program."""
    armt_on = serve_mode == "armt" and cfg.armt is not None

    def chunk_fn(params, state, tok, active, remaining):
        def body(carry, _):
            state, tok, active, remaining = carry
            emit, emit_mask = tok, active
            logits, new_state = decode_step(params, cfg, state, tok,
                                            serve_mode=serve_mode)
            # freeze EVERY leaf of inactive slots' rows, not just pos:
            # garbage rows never trigger (or mask into) a flush, their SSM
            # carries and cache offsets stop drifting, and — load-bearing
            # for the session store — a row that finished mid-chunk stays
            # bit-exactly at its end-of-generation state until the host
            # extracts it at the chunk boundary
            new_state = mask_decode_state(active, new_state, state)
            if armt_on:
                boundary = active & (new_state["pos"] >= seg_len)
                new_state = jax.lax.cond(
                    boundary.any(),
                    lambda s: flush_segment(params, cfg, s,
                                            slot_mask=boundary),
                    lambda s: s, new_state)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            nxt = jnp.where(active, nxt, tok)
            remaining = remaining - emit_mask.astype(jnp.int32)
            active = active & (remaining > 0)
            return (new_state, nxt, active, remaining), (emit, emit_mask)

        # named_scope: XLA profiles label this scan to match the host-side
        # decode_chunk spans (DESIGN.md §13)
        with jax.named_scope("serve.decode_chunk"):
            (state, tok, active, remaining), (toks, masks) = jax.lax.scan(
                body, (state, tok, active, remaining), None, length=chunk)
        return state, tok, active, remaining, toks, masks

    return chunk_fn


def scheduler_fns(engine, chunk: int):
    """Build (or fetch from the engine's cache) the jitted packed-chunk,
    admission, and slot-extraction functions shared by every scheduler on
    this engine."""
    cache = engine._sched_fns
    if chunk in cache:
        return cache[chunk]
    donate_ok = jax.default_backend() != "cpu"
    chunk_fn = _chunk_body_factory(engine.cfg, engine.serve_mode,
                                   engine.seg_len, chunk)

    def admit_fn(pool, tok, active, remaining, slot, one_state,
                 first_tok, pos_val, n_new):
        prelude = jax.tree_util.tree_map(
            lambda pl, ol: pl.at[slot].set(ol[0].astype(pl.dtype)),
            tuple(pool["prelude"]), tuple(one_state["prelude"]))
        pattern = jax.tree_util.tree_map(
            lambda pl, ol: pl.at[:, slot].set(ol[:, 0].astype(pl.dtype)),
            tuple(pool["pattern"]), tuple(one_state["pattern"]))
        new_pool = {"prelude": prelude, "pattern": pattern,
                    "pos": pool["pos"].at[slot].set(pos_val)}
        return (new_pool,
                tok.at[slot].set(first_tok),
                active.at[slot].set(True),
                remaining.at[slot].set(n_new))

    def extract_fn(pool, tok, slot):
        """Inverse of admit_fn's transplant: lift slot row -> B=1 state
        (device-side; the host only pulls it when persisting a session)."""
        prelude = jax.tree_util.tree_map(
            lambda pl: jax.lax.dynamic_slice_in_dim(pl, slot, 1, axis=0),
            tuple(pool["prelude"]))
        pattern = jax.tree_util.tree_map(
            lambda pl: jax.lax.dynamic_slice_in_dim(pl, slot, 1, axis=1),
            tuple(pool["pattern"]))
        return ({"prelude": prelude, "pattern": pattern},
                pool["pos"][slot], tok[slot])

    fns = (jax.jit(chunk_fn, donate_argnums=(1, 2, 3, 4) if donate_ok else ()),
           jax.jit(admit_fn, donate_argnums=(0, 1, 2, 3) if donate_ok else ()),
           jax.jit(extract_fn))
    cache[chunk] = fns
    return fns


def fused_fns(engine, chunk: int, n_segments: int, capture: bool, k: int):
    """Jitted combined program for the *fused* admission mode (DESIGN.md
    §11): one launch runs the packed decode chunk over every slot AND ``k``
    anti-diagonal groups of the admitting request's suspended pipeline, so
    the admission's segment-cells ride the same dispatch window as the
    decode cells — XLA schedules both inside a single program (and both go
    through the grouped Pallas kernels when the engine runs
    grouped_impl='fused'). Donates the pool/control vectors and the
    pipeline carry (never the read-only ``xs``) on backends that honor
    donation; the carry therefore must be fresh-buffered at pipeline start
    (see serve.engine.PrefillPipeline)."""
    key = (chunk, n_segments, capture, k)
    cache = engine._fused_fns
    if key in cache:
        return cache[key]
    from repro.core import diagonal as diag
    from repro.core.schedule import StackLayout
    cfg = engine.cfg
    chunk_body = _chunk_body_factory(cfg, engine.serve_mode, engine.seg_len,
                                     chunk)
    layout = StackLayout.from_config(cfg)
    # the same apply/grouped pair the plain stepper uses — one source of
    # truth for the numerics-critical executor setup (engine.exec_apply)
    apply, gapply = engine.exec_apply()
    buf_spec = engine._slot_spec(1)      # admissions are B=1

    def fused(params, state, tok, active, remaining, xs, carry):
        with jax.named_scope("serve.fused_global_grid"):
            state, tok, active, remaining, toks, masks = chunk_body(
                params, state, tok, active, remaining)
            exec_params = {"prelude": params["prelude"],
                           "pattern": params["pattern"]}
            carry = diag.pipeline_step(layout, exec_params, xs, carry,
                                       apply, n_groups=k, buf_spec=buf_spec,
                                       grouped_apply=gapply,
                                       remat=cfg.remat != "none",
                                       retain_pos=engine.seg_len - 1)
        return state, tok, active, remaining, toks, masks, carry

    donate = (1, 2, 3, 4, 6) if jax.default_backend() != "cpu" else ()
    cache[key] = jax.jit(fused, donate_argnums=donate)
    return cache[key]


def fused_pool_fns(engine, chunk: int, sigs: tuple):
    """Jitted GLOBAL-GRID program (DESIGN.md §12): one launch runs the
    packed decode chunk over every slot AND k anti-diagonal groups from
    every pooled admission bucket — the whole round's ready cells, decode
    and N admissions alike, in a single dispatch (the N-carry
    generalization of ``fused_fns``).

    ``sigs`` is the per-bucket signature tuple ``((n_segments, capture,
    stream, k, n_pool), ...)``; the program takes (and returns) one
    ``(xs_tuple, carry_tuple)`` pair per bucket, each tuple pow2-padded to
    its ``n_pool`` (engine.pool_pack), so the compile count is bounded by
    the pow2 bucketing of both stage sizes and pool sizes times the few
    bucket combinations a workload actually produces. Donates the
    pool/control vectors and every carry tuple (never the read-only xs) on
    backends that honor donation."""
    key = ("pool", chunk) + tuple(sigs)
    cache = engine._fused_fns
    if key in cache:
        return cache[key]
    chunk_body = _chunk_body_factory(engine.cfg, engine.serve_mode,
                                     engine.seg_len, chunk)
    bodies = [engine._pool_step_body(g, 1, capture, k, n_pool)
              for (g, capture, _stream, k, n_pool) in sigs]

    def fused(params, state, tok, active, remaining, xs_bkts, carry_bkts):
        with jax.named_scope("serve.fused_global_grid"):
            state, tok, active, remaining, toks, masks = chunk_body(
                params, state, tok, active, remaining)
            out_bkts = tuple(body(params, xs_t, carry_t)
                             for body, xs_t, carry_t
                             in zip(bodies, xs_bkts, carry_bkts))
        return state, tok, active, remaining, toks, masks, out_bkts

    donate = (1, 2, 3, 4, 6) if jax.default_backend() != "cpu" else ()
    cache[key] = jax.jit(fused, donate_argnums=donate)
    return cache[key]
