"""Batched long-context serving engine.

Prefill uses the *diagonal* schedule over full segments (the paper's win:
one long request keeps the GPU/TPU busy without cross-request batching),
then transplants the executor's per-layer memory states into the decode
state; the prompt tail and new tokens run through `decode_step`, with ARMT
segment flushes at segment boundaries (constant memory in context length).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.models import (decode_state_init, decode_step, flush_segment,
                          forward_hidden, last_logits)


def _transplant(fin: Dict, dstate: Dict) -> Dict:
    """Copy recurrent state (A/z/h/conv) from executor final state into the
    decode state (which additionally holds kv caches and pos)."""
    def merge_one(src: Dict, dst: Dict) -> Dict:
        out = dict(dst)
        for k in ("A", "z", "h", "conv"):
            if k in src:
                out[k] = src[k].astype(dst[k].dtype) if hasattr(dst.get(k), "dtype") else src[k]
        return out

    new_prelude = tuple(merge_one(s, d) for s, d in
                        zip(fin["prelude"], dstate["prelude"]))
    new_pattern = tuple(merge_one(s, d) for s, d in
                        zip(fin["pattern"], dstate["pattern"]))
    return {"prelude": new_prelude, "pattern": new_pattern,
            "pos": dstate["pos"]}


@dataclass
class GenerationResult:
    tokens: np.ndarray          # [B, max_new]
    prefill_segments: int
    schedule: str


class ServeEngine:
    """Compile-once engine for a fixed (batch, prompt_len, max_new) shape.

    serve_mode 'armt': constant-memory decode (paper Fig. 1); 'cache':
    standard full-KV decoding for the baseline comparison.
    """

    def __init__(self, params, cfg: ArchConfig, *, serve_mode: str = "armt",
                 schedule: str = "diagonal", max_len: int = 8192,
                 grouped_impl: Optional[str] = None):
        self.params = params
        self.cfg = cfg
        self.serve_mode = serve_mode
        self.schedule = schedule
        self.max_len = max_len
        # 'fused' routes diagonal prefill through the grouped Pallas kernels
        # (models/grouped_blocks.py); None defers to cfg.grouped_impl.
        self.grouped_impl = grouped_impl
        self.seg_len = cfg.armt.segment_len if cfg.armt else 1024
        self._step = jax.jit(
            lambda p, s, t: decode_step(p, cfg, s, t, serve_mode=serve_mode))
        self._flush = jax.jit(
            lambda p, s: flush_segment(p, cfg, s)) if cfg.armt else None

    def prefill(self, prompts: jax.Array, enc_frames=None):
        """prompts: [B, P]. Returns (next_token_logits, decode_state)."""
        logits, dstate, _ = self._prefill(prompts, enc_frames=enc_frames)
        return logits, dstate

    def _prefill(self, prompts: jax.Array, enc_frames=None):
        B, P = prompts.shape
        dtype = self.params["embed"].dtype
        dstate = decode_state_init(self.cfg, B, serve_mode=self.serve_mode,
                                   max_len=self.max_len, dtype=dtype)
        n_full = P // self.seg_len if self.serve_mode == "armt" else 0
        logits = None
        if n_full > 0:
            hidden, fin = forward_hidden(
                self.params, self.cfg, prompts[:, :n_full * self.seg_len],
                schedule=self.schedule, enc_frames=enc_frames,
                grouped_impl=self.grouped_impl)
            dstate = _transplant(fin, dstate)
            logits = last_logits(self.params, self.cfg, hidden)
        tail = prompts[:, n_full * self.seg_len:]
        pos = 0                       # host-side segment position (no sync)
        if tail.shape[1] > 0:
            logits, dstate, pos = self._chunk(dstate, tail, pos)
        return logits, dstate, pos

    def _maybe_flush(self, dstate, pos: int):
        """ARMT segment boundary: flush memory and reset the segment cache.
        ``pos`` is tracked host-side — decode_step advances the device-side
        ``dstate['pos']`` by exactly the tokens fed, so the two never diverge
        and no device->host readback is needed per step."""
        if (self.serve_mode == "armt" and self.cfg.armt
                and pos >= self.seg_len):
            return self._flush(self.params, dstate), 0
        return dstate, pos

    def _chunk(self, dstate, toks, pos: int):
        """Feed a multi-token chunk, flushing at ARMT segment boundaries."""
        logits = None
        t = 0
        T = toks.shape[1]
        while t < T:
            room = (self.seg_len - pos
                    if self.serve_mode == "armt" else T - t)
            take = min(room, T - t)
            logits, dstate = self._step(self.params, dstate,
                                        toks[:, t:t + take])
            t += take
            pos += take
            dstate, pos = self._maybe_flush(dstate, pos)
        return logits, dstate, pos

    def generate(self, prompts: jax.Array, max_new: int,
                 enc_frames=None) -> GenerationResult:
        logits, dstate, pos = self._prefill(prompts, enc_frames=enc_frames)
        B = prompts.shape[0]
        out = np.zeros((B, max_new), np.int32)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for i in range(max_new):
            out[:, i] = np.asarray(tok)
            if i == max_new - 1:
                break
            logits, dstate = self._step(self.params, dstate, tok)
            pos += 1
            dstate, pos = self._maybe_flush(dstate, pos)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return GenerationResult(out, prompts.shape[1] // self.seg_len,
                                self.schedule)
