"""Batched long-context serving engine.

Prefill uses the *diagonal* schedule over full segments (the paper's win:
one long request keeps the GPU/TPU busy without cross-request batching),
then transplants the executor's per-layer memory states into the decode
state; the prompt tail and new tokens run through `decode_step`, with ARMT
segment flushes at segment boundaries (constant memory in context length).

Decode runs entirely on device: a `jax.lax.scan` over steps with the state
donated to the jitted loop, segment flushes folded in as a `lax.cond`, and
greedy/temperature/top-k sampling applied to the logits on device — the
host sees tokens once per `generate` call (zero per-token device->host
transfers), not once per token.

Multi-request continuous batching lives in `serve/scheduler.py`; the
`ServeEngine.serve(requests)` iterator is the streaming front door.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.models import (decode_state_init, decode_step, flush_segment,
                          forward_hidden, last_logits)


def _transplant(fin: Dict, dstate: Dict) -> Dict:
    """Copy recurrent state (A/z/h/conv) from executor final state into the
    decode state (which additionally holds kv caches and pos)."""
    def merge_one(src: Dict, dst: Dict) -> Dict:
        out = dict(dst)
        for k in ("A", "z", "h", "conv"):
            if k in src:
                out[k] = src[k].astype(dst[k].dtype) if hasattr(dst.get(k), "dtype") else src[k]
        return out

    new_prelude = tuple(merge_one(s, d) for s, d in
                        zip(fin["prelude"], dstate["prelude"]))
    new_pattern = tuple(merge_one(s, d) for s, d in
                        zip(fin["pattern"], dstate["pattern"]))
    return {"prelude": new_prelude, "pattern": new_pattern,
            "pos": dstate["pos"]}


@dataclass
class GenerationResult:
    tokens: np.ndarray          # [B, max_new]
    prefill_segments: int
    schedule: str


class ServeEngine:
    """Compile-once engine for a fixed (batch, prompt_len, max_new) shape.

    serve_mode 'armt': constant-memory decode (paper Fig. 1); 'cache':
    standard full-KV decoding for the baseline comparison.
    """

    def __init__(self, params, cfg: ArchConfig, *, serve_mode: str = "armt",
                 schedule: str = "diagonal", max_len: int = 8192,
                 grouped_impl: Optional[str] = None):
        if serve_mode not in ("armt", "cache"):
            raise ValueError(f"unknown serve_mode {serve_mode!r}")
        if serve_mode == "armt" and cfg.armt is None and not cfg.is_recurrent:
            # used to silently fall back to seg_len=1024: attention layers
            # then never flush and segments of the prefill are disconnected
            # contexts — constant-memory serving is simply undefined here
            raise ValueError(
                f"serve_mode='armt' needs recurrent layer state, but "
                f"{cfg.name} has cfg.armt=None and non-SSM layers — pass "
                "serve_mode='cache' for full-KV decoding or add an "
                "ARMTConfig to the arch")
        self.params = params
        self.cfg = cfg
        self.serve_mode = serve_mode
        self.schedule = schedule
        self.max_len = max_len
        # 'fused' routes diagonal prefill through the grouped Pallas kernels
        # (models/grouped_blocks.py); None defers to cfg.grouped_impl.
        self.grouped_impl = grouped_impl
        # pure-SSM archs have no segment boundaries: state carries across
        # arbitrary chunk sizes, so 'one chunk' (max_len) replaces the old
        # silent seg_len=1024 fallback
        self.seg_len = cfg.armt.segment_len if cfg.armt else max_len
        self._step = jax.jit(
            lambda p, s, t: decode_step(p, cfg, s, t, serve_mode=serve_mode))
        self._flush = jax.jit(
            lambda p, s: flush_segment(p, cfg, s)) if cfg.armt else None
        self._loops: Dict = {}    # (max_new, greedy, top_k) -> jitted loop
        self._sched_fns: Dict = {}   # chunk -> jitted scheduler fns (shared
        #                              across serve() calls / slot counts)

    def prefill(self, prompts: jax.Array, enc_frames=None):
        """prompts: [B, P]. Returns (next_token_logits, decode_state)."""
        logits, dstate, _ = self._prefill(prompts, enc_frames=enc_frames)
        return logits, dstate

    def _prefill(self, prompts: jax.Array, enc_frames=None):
        B, P = prompts.shape
        dtype = self.params["embed"].dtype
        dstate = decode_state_init(self.cfg, B, serve_mode=self.serve_mode,
                                   max_len=self.max_len, dtype=dtype)
        n_full = P // self.seg_len if self.serve_mode == "armt" else 0
        logits = None
        if n_full > 0:
            hidden, fin = forward_hidden(
                self.params, self.cfg, prompts[:, :n_full * self.seg_len],
                schedule=self.schedule, enc_frames=enc_frames,
                grouped_impl=self.grouped_impl)
            dstate = _transplant(fin, dstate)
            logits = last_logits(self.params, self.cfg, hidden)
        tail = prompts[:, n_full * self.seg_len:]
        pos = 0                       # host-side segment position (no sync)
        if tail.shape[1] > 0:
            logits, dstate, pos = self._chunk(dstate, tail, pos)
        return logits, dstate, pos

    def _maybe_flush(self, dstate, pos: int):
        """ARMT segment boundary: flush memory and reset the segment cache.
        ``pos`` is tracked host-side — decode_step advances the device-side
        ``dstate['pos']`` by exactly the tokens fed, so the two never diverge
        and no device->host readback is needed per step."""
        if (self.serve_mode == "armt" and self.cfg.armt
                and pos >= self.seg_len):
            return self._flush(self.params, dstate), 0
        return dstate, pos

    def _chunk(self, dstate, toks, pos: int):
        """Feed a multi-token chunk, flushing at ARMT segment boundaries."""
        logits = None
        t = 0
        T = toks.shape[1]
        while t < T:
            room = (self.seg_len - pos
                    if self.serve_mode == "armt" else T - t)
            take = min(room, T - t)
            logits, dstate = self._step(self.params, dstate,
                                        toks[:, t:t + take])
            t += take
            pos += take
            dstate, pos = self._maybe_flush(dstate, pos)
        return logits, dstate, pos

    # ------------------------------------------------------------------
    # On-device decode loop
    # ------------------------------------------------------------------

    def _decode_loop(self, max_new: int, greedy: bool, top_k: int):
        """Build (and cache) the jitted whole-decode loop: a lax.scan over
        steps that samples, steps, and flushes at segment boundaries via
        lax.cond — no host branching, no per-token device->host transfer.
        The decode state is donated to the loop (freely overwritten in
        place on backends that support donation)."""
        key_ = (max_new, greedy, top_k)
        if key_ in self._loops:
            return self._loops[key_]
        cfg, serve_mode, seg_len = self.cfg, self.serve_mode, self.seg_len
        armt_on = serve_mode == "armt" and cfg.armt is not None

        def loop(params, dstate, logits0, temp, rng):
            def sample(logits, k):
                # `temp` stays a traced scalar so changing the temperature
                # value never recompiles; greedy vs sampling is a different
                # graph (keyed in self._loops)
                if greedy:
                    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
                scaled = logits / temp
                if top_k > 0:
                    kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
                    scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
                return jax.random.categorical(k, scaled, -1).astype(jnp.int32)

            def body(carry, key_t):
                state, tok = carry
                logits, state = decode_step(params, cfg, state, tok,
                                            serve_mode=serve_mode)
                if armt_on:
                    state = jax.lax.cond(
                        state["pos"] >= seg_len,
                        lambda s: flush_segment(params, cfg, s),
                        lambda s: s, state)
                nxt = sample(logits, key_t)
                return (state, nxt), nxt

            # token 0 comes from the prefill logits; the scan emits the
            # max_new-1 stepped samples, so the last emitted token is never
            # fed through a wasted forward
            keys = jax.random.split(rng, max_new)
            tok0 = sample(logits0, keys[0])
            (_, _), toks = jax.lax.scan(body, (dstate, tok0), keys[1:])
            return jnp.concatenate([tok0[None], toks], axis=0).T  # [B, max_new]

        # donation is a no-op (with a warning) on CPU — only request it where
        # the backend honors it
        donate = (1,) if jax.default_backend() != "cpu" else ()
        self._loops[key_] = jax.jit(loop, donate_argnums=donate)
        return self._loops[key_]

    def generate(self, prompts: jax.Array, max_new: int, *,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 enc_frames=None) -> GenerationResult:
        """Prefill + decode max_new tokens. temperature<=0: greedy (the
        default, deterministic); otherwise temperature/top-k sampling with
        an on-device PRNG. One device->host transfer for the whole call."""
        if (self.serve_mode == "cache"
                and prompts.shape[1] + max_new > self.max_len):
            # the KV write offset would clamp at the cache end and silently
            # corrupt logits — refuse instead
            raise ValueError(
                f"prompt_len {prompts.shape[1]} + max_new {max_new} exceeds "
                f"max_len {self.max_len} of the KV cache")
        logits, dstate, _pos = self._prefill(prompts, enc_frames=enc_frames)
        loop = self._decode_loop(max_new, temperature <= 0.0, top_k)
        toks = loop(self.params, dstate, logits,
                    jnp.float32(max(temperature, 1e-6)),
                    jax.random.PRNGKey(seed))
        return GenerationResult(np.asarray(toks),
                                prompts.shape[1] // self.seg_len,
                                self.schedule)

    # ------------------------------------------------------------------
    # Continuous batching
    # ------------------------------------------------------------------

    def serve(self, requests: Iterable, *, n_slots: int = 4,
              chunk: int = 8) -> Iterator:
        """Continuous-batching streaming front door: admit `Request`s into a
        fixed pool of decode slots and yield `StreamEvent`s as tokens are
        produced (see serve/scheduler.py for the slot-state invariants)."""
        from repro.serve.scheduler import ContinuousScheduler
        sched = ContinuousScheduler(self, n_slots=n_slots, chunk=chunk)
        return sched.run(requests)
