"""Batched long-context serving engine.

Prefill uses the *diagonal* schedule over full segments (the paper's win:
one long request keeps the GPU/TPU busy without cross-request batching),
then transplants the executor's per-layer memory states into the decode
state; the prompt tail and new tokens run through `decode_step`, with ARMT
segment flushes at segment boundaries (constant memory in context length).

Decode runs entirely on device: a `jax.lax.scan` over steps with the state
donated to the jitted loop, segment flushes folded in as a `lax.cond`, and
greedy/temperature/top-k sampling applied to the logits on device — the
host sees tokens once per `generate` call (zero per-token device->host
transfers), not once per token.

Prompt shapes are *bucketed* (on by default): full segments run in
descending power-of-two groups with the executor state threaded through,
and sub-segment tails feed `decode_step` in descending power-of-two
chunks — so the engine compiles O(log) distinct prefill shapes instead of
one per prompt length the scheduler ever sees. Bucketing is pure
re-chunking of the exact same tokens (never padding), so it is
token-identical to the unbucketed path by construction (tested).

The engine optionally carries a serving state store (serve/state_store.py):
a segment-granular `PrefixCache` (longest-prefix match at admission, so
only uncached tail segments are prefilled) and a `SessionStore` (multi-turn
resume via `generate(..., session_id=...)` — O(new turn) instead of
re-prefilling the conversation).

The whole stack is *mesh-native* (DESIGN.md §10): `ServeEngine(mesh=...)`
derives placement from `parallel/sharding.py` rules — params over 'model'
(TP, stacked pattern optionally over 'stage'), the diagonal prefill's slot
buffer pipeline-sharded via `slot_buf_spec`, decode state with batch/slots
over the DP axes and heads/d_model over 'model' — and every jitted graph
(`decode_step`, `flush_segment`, the whole-decode `lax.scan`, the
scheduler's packed chunk/admission/extract) stays a single program with
GSPMD inserting the collectives. State-store blobs cross the mesh boundary
host-portable (gather-on-capture in the store, `_place_state`
scatter-on-restore here), so snapshots resume across different mesh shapes.

Prefill is also available as a *resumable pipeline* (DESIGN.md §11):
`start_prefill` returns a `PrefillPipeline` whose `advance()` runs one
bounded unit — `prefill_groups_per_chunk` anti-diagonal groups via the
jitted `prefill_step` stepper (carry donated), or one tail `decode_step`
piece — so the continuous-batching scheduler interleaves a new request's
admission with decode chunks instead of blocking every slot for the whole
prompt. The pipeline shares the one-shot executor's step body bit for bit
and `_prefill`'s stage/piece decomposition, so it is token-identical
(greedy) to the blocking path by construction.

Multi-request continuous batching lives in `serve/scheduler.py`; the
`ServeEngine.serve(requests)` iterator is the streaming front door.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass
from typing import Dict, Iterable, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.core import diagonal as diag
from repro.core.memory import RECURRENT_KEYS
from repro.core.schedule import StackLayout, n_diagonal_groups
from repro.models import (boundary_logits, decode_state_init,
                          decode_state_sharding, decode_step, embed_segments,
                          flush_segment, forward_hidden, init_state,
                          last_logits)
from repro.parallel import sharding as shd
from repro.serve.telemetry import Telemetry


def _transplant(fin: Dict, dstate: Dict) -> Dict:
    """Copy recurrent state (RECURRENT_KEYS: A/z/h/conv) from an executor
    final state or boundary snapshot into the decode state (which
    additionally holds kv caches and pos)."""
    def merge_one(src: Dict, dst: Dict) -> Dict:
        out = dict(dst)
        for k in RECURRENT_KEYS:
            if k in src:
                out[k] = src[k].astype(dst[k].dtype) if hasattr(dst.get(k), "dtype") else src[k]
        return out

    new_prelude = tuple(merge_one(s, d) for s, d in
                        zip(fin["prelude"], dstate["prelude"]))
    new_pattern = tuple(merge_one(s, d) for s, d in
                        zip(fin["pattern"], dstate["pattern"]))
    return {"prelude": new_prelude, "pattern": new_pattern,
            "pos": dstate["pos"]}


def _pow2_chunks(n: int) -> List[int]:
    """Descending power-of-two decomposition of n (13 -> [8, 4, 1]) — the
    length buckets that keep prefill compile counts logarithmic."""
    out = []
    while n > 0:
        p = 1 << (n.bit_length() - 1)
        out.append(p)
        n -= p
    return out


@dataclass
class GenerationResult:
    tokens: np.ndarray          # [B, max_new]
    prefill_segments: int
    schedule: str
    # serving metrics (host-clock; decode is one device call, so TTFT is the
    # prefill/admission wall time — the quantity prefix caching attacks)
    ttft_s: float = 0.0
    tok_s: float = 0.0          # decode throughput after first token
    cached_segments: int = 0    # segments transplanted from the prefix cache
    session_id: Optional[str] = None
    resumed: bool = False       # True when restored from the session store
    # queue-wait breakdown, mirroring StreamEvent (DESIGN.md §12): direct
    # generate() calls never queue, so these stay at their idle defaults —
    # they exist so result records from both front doors aggregate uniformly
    queue_wait_s: float = 0.0
    concurrent_admissions: int = 1
    # telemetry snapshot at result time (DESIGN.md §13): the engine's
    # metrics registry — compile counts, store stats, serving histograms —
    # as a JSON-able dict; None when the engine's telemetry is disabled
    metrics: Optional[Dict] = None


class ServeEngine:
    """Compile-once engine for a fixed (batch, prompt_len, max_new) shape.

    serve_mode 'armt': constant-memory decode (paper Fig. 1); 'cache':
    standard full-KV decoding for the baseline comparison.

    prefix_cache / session_store: optional serving state stores
    (serve/state_store.py). The prefix cache needs serve_mode='armt' (its
    snapshots are the constant-size recurrent memory; a 'cache'-mode prefix
    would be the full KV tensor — exactly what the RMT lets us avoid).

    mesh: optional `jax.sharding.Mesh` — the engine becomes mesh-native
    (DESIGN.md §10): params are device_put to `parallel/sharding.py` specs
    (TP over 'model', stacked pattern over 'stage' when present), decode
    states to `decode_state_sharding` (batch/slots over DP axes), and the
    diagonal prefill runs with `slot_buf_spec` pipeline sharding. All
    decode/serve math is unchanged — GSPMD derives the collectives from the
    argument placements, so sharded serving is token-identical (greedy) to
    the single-device engine (tests/test_serve_sharded.py).
    """

    def __init__(self, params, cfg: ArchConfig, *, serve_mode: str = "armt",
                 schedule: str = "diagonal", max_len: int = 8192,
                 grouped_impl: Optional[str] = None,
                 prefix_cache=None, session_store=None,
                 bucket_prompts: bool = True, mesh=None,
                 telemetry: Optional[Telemetry] = None):
        if serve_mode not in ("armt", "cache"):
            raise ValueError(f"unknown serve_mode {serve_mode!r}")
        if serve_mode == "armt" and cfg.armt is None and not cfg.is_recurrent:
            # used to silently fall back to seg_len=1024: attention layers
            # then never flush and segments of the prefill are disconnected
            # contexts — constant-memory serving is simply undefined here
            raise ValueError(
                f"serve_mode='armt' needs recurrent layer state, but "
                f"{cfg.name} has cfg.armt=None and non-SSM layers — pass "
                "serve_mode='cache' for full-KV decoding or add an "
                "ARMTConfig to the arch")
        self.cfg = cfg
        self.serve_mode = serve_mode
        self.schedule = schedule
        self.max_len = max_len
        # 'fused' routes diagonal prefill through the grouped Pallas kernels
        # (models/grouped_blocks.py); None defers to cfg.grouped_impl.
        self.grouped_impl = grouped_impl
        # pure-SSM archs have no segment boundaries: state carries across
        # arbitrary chunk sizes, so 'one chunk' (max_len) replaces the old
        # silent seg_len=1024 fallback
        self.seg_len = cfg.armt.segment_len if cfg.armt else max_len
        if prefix_cache is not None:
            if serve_mode != "armt":
                raise ValueError(
                    "prefix_cache needs serve_mode='armt' — its snapshots "
                    "are the constant-size recurrent memory at segment "
                    "boundaries, which full-KV 'cache' mode does not have")
            if prefix_cache.seg_len != self.seg_len:
                raise ValueError(
                    f"prefix_cache.seg_len {prefix_cache.seg_len} != engine "
                    f"segment length {self.seg_len}: boundary hashes would "
                    "never match this engine's prefill boundaries")
        self.prefix_cache = prefix_cache
        self.session_store = session_store
        self.bucket_prompts = bucket_prompts
        self.mesh = mesh
        self.stacked_axis = (
            "stage" if mesh is not None and "stage" in mesh.axis_names
            else None)
        if mesh is not None:
            # params committed to their TP/stage shardings once, up front —
            # every jitted graph below then inherits the placement and GSPMD
            # inserts the collectives (no per-call resharding)
            pspecs = shd.param_specs(params, mesh,
                                     stacked_axis=self.stacked_axis)
            params = jax.device_put(params, pspecs)
        self.params = params
        self._n_layers = StackLayout.from_config(cfg).n_layers
        self._step = jax.jit(
            lambda p, s, t: decode_step(p, cfg, s, t, serve_mode=serve_mode))
        self._flush = jax.jit(
            lambda p, s: flush_segment(p, cfg, s)) if cfg.armt else None
        self._loops: Dict = {}    # (max_new, greedy, top_k) -> jitted loop
        self._sched_fns: Dict = {}   # chunk -> jitted scheduler fns (shared
        #                              across serve() calls / slot counts)
        self._pipe_steps: Dict = {}  # (S, B, capture, k) -> jitted
        #                              prefill_step (resumable pipeline §11)
        self._fused_fns: Dict = {}   # (chunk, S, capture, k) -> fused
        #                              decode-chunk + prefill-step program
        #                              (and pooled variants, §12 — keyed
        #                              ('pool', chunk, bucket-signatures))
        self._pool_steps: Dict = {}  # (S, B, capture, k, n_pool) -> jitted
        #                              pooled stepper (admission pool §12)
        self._mem_stats: Dict = {}   # (S, B, capture, stream, k) -> compiled
        #                              prefill memory_analysis (§15 budget)
        # observability (DESIGN.md §13): metrics into the process default
        # registry unless told otherwise; spans only when a recorder was
        # asked for. Host-side only — never adds a device sync.
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        reg = self.telemetry.registry
        if reg is not None:
            # probes are sampled at snapshot time, so compile counts and
            # store stats are always current with zero per-chunk bookkeeping
            reg.register_probe("engine_compile_counts", self.compile_counts)
            if prefix_cache is not None:
                reg.register_probe(
                    "prefix_cache", lambda: self.prefix_cache.stats.as_dict())
            if session_store is not None:
                reg.register_probe(
                    "session_store",
                    lambda: self.session_store.stats.as_dict())

    # ------------------------------------------------------------------
    # Observability (DESIGN.md §13)
    # ------------------------------------------------------------------

    def compile_counts(self) -> Dict[str, int]:
        """Compiled-program counts per jit-cache kind, from the jitted
        functions' own trace caches — the per-signature ground truth behind
        the pow2-bucketing "O(log) compiles" claim (the registry's
        ``xla_backend_compiles_total`` counter cross-checks it at the XLA
        layer)."""
        def sz(fn):
            return fn._cache_size() if hasattr(fn, "_cache_size") else 0

        counts = {
            "decode_step": sz(self._step),
            "flush": sz(self._flush) if self._flush is not None else 0,
            "decode_loops": sum(sz(f) for f in self._loops.values()),
            "scheduler_fns": sum(sz(f) for fns in self._sched_fns.values()
                                 for f in fns),
            "prefill_steps": sum(sz(f) for f in self._pipe_steps.values()),
            "fused": sum(sz(f) for f in self._fused_fns.values()),
            "pool_steps": sum(sz(f) for f in self._pool_steps.values()),
        }
        counts["total"] = sum(counts.values())
        return counts

    def metrics_snapshot(self) -> Dict:
        """Registry snapshot plus the engine's own probes flattened in —
        what ``launch/serve.py --metrics`` dumps and ``bench_serve.py``
        embeds into BENCH_serve.json."""
        snap = self.telemetry.snapshot() or {}
        snap["compile_counts"] = self.compile_counts()
        if self.prefix_cache is not None:
            snap["prefix_cache"] = self.prefix_cache.stats.as_dict()
        if self.session_store is not None:
            snap["session_store"] = self.session_store.stats.as_dict()
        return snap

    # ------------------------------------------------------------------
    # Mesh placement (DESIGN.md §10) — no-ops on a mesh-less engine
    # ------------------------------------------------------------------

    def state_sharding(self, batch: int, *, per_slot_pos: bool = False):
        """Decode-state NamedSharding tree for this engine's placement
        rules; None without a mesh."""
        if self.mesh is None:
            return None
        return decode_state_sharding(
            self.cfg, self.mesh, batch, serve_mode=self.serve_mode,
            max_len=self.max_len, dtype=self.params["embed"].dtype,
            per_slot_pos=per_slot_pos,
            stacked_axis=self.stacked_axis)

    def _place_state(self, tree, batch: int):
        """Scatter-on-restore: commit a decode/recurrent state tree (possibly
        host numpy out of a mesh-agnostic store blob) to this engine's
        shardings. The tree may be a sub-tree of a full decode state (e.g. a
        boundary snapshot without pos/kv) — specs are derived from the tree
        itself, so any {'prelude','pattern'} layout works.

        Always a *fresh* buffer, never the store's own arrays — load-bearing:
        on an exact full-prefix hit with no tail the transplanted leaves
        reach the decode loop unmodified, and that loop donates its state;
        without a fresh buffer, donation would delete the cache entry's
        arrays out from under the store and the next hit on the same prefix
        would transplant deleted arrays (GPU/TPU only; donation is skipped
        on CPU, so CPU tests can't catch it)."""
        if self.mesh is None:
            return jax.tree_util.tree_map(lambda a: jnp.array(a, copy=True),
                                          tree)
        specs = shd.decode_state_specs(tree, self.mesh, batch,
                                       stacked_axis=self.stacked_axis)

        def one(a, s):
            # device_put can alias the input's buffers when the placement
            # already matches (including the zero-copy commit of an
            # uncommitted array) — copy device arrays first so the result
            # never shares storage with the store. Host numpy leaves skip
            # the copy: device_put from host always allocates fresh device
            # buffers.
            if isinstance(a, jax.Array):
                a = jnp.array(a, copy=True)
            return jax.device_put(a, s)

        return jax.tree_util.tree_map(one, tree, specs)

    def _slot_spec(self, batch: int):
        """Diagonal slot-buffer PartitionSpec for prefill on this mesh."""
        if self.mesh is None or self.schedule != "diagonal":
            return None
        return shd.slot_buf_spec(self.mesh, self._n_layers, batch)

    def prefill(self, prompts: jax.Array, enc_frames=None):
        """prompts: [B, P]. Returns (next_token_logits, decode_state)."""
        logits, dstate, _, _ = self._prefill(prompts, enc_frames=enc_frames)
        return logits, dstate

    # ------------------------------------------------------------------
    # Prefill: diagonal full segments (+ prefix cache) then bucketed tail
    # ------------------------------------------------------------------

    def _mesh_ctx(self):
        """Ambient-mesh context: the diagonal executor (and the pipeline
        stepper) constrain buffers with raw PartitionSpecs, which resolve
        against the ambient mesh — enter it around any prefill trace."""
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _forward(self, toks, exec_state, enc_frames, capture: bool):
        with self._mesh_ctx():
            return forward_hidden(
                self.params, self.cfg, toks, schedule=self.schedule,
                enc_frames=enc_frames, grouped_impl=self.grouped_impl,
                slot_spec=self._slot_spec(toks.shape[0]),
                init_state=exec_state, capture_states=capture)

    def _prefill(self, prompts: jax.Array, enc_frames=None):
        """prompts: [B, P]. Returns (next_token_logits, decode_state,
        in-segment pos, cached_segments)."""
        B, P = prompts.shape
        dtype = self.params["embed"].dtype
        dstate = decode_state_init(self.cfg, B, serve_mode=self.serve_mode,
                                   max_len=self.max_len, dtype=dtype)
        if self.mesh is not None:
            dstate = jax.device_put(dstate, self.state_sharding(B))
        n_full = P // self.seg_len if self.serve_mode == "armt" else 0
        logits = None
        cached = 0
        exec_state = None
        prompt_np = None
        # prefix caching is per-request (B=1 — the scheduler's admission
        # shape) and needs token-addressable segments, which encoder archs'
        # frame inputs are not
        use_cache = (self.prefix_cache is not None and B == 1
                     and enc_frames is None and n_full > 0)
        chain = None
        if use_cache:
            from repro.serve.state_store import prefix_hash_chain
            prompt_np = np.asarray(prompts[0], np.int32)
            chain = prefix_hash_chain(prompt_np, self.seg_len)
            with self.telemetry.span("prefix_probe", "cache",
                                     n_segments=n_full):
                cached, snap = self.prefix_cache.match(prompt_np, chain=chain)
            self.telemetry.inc("prefix_probe_total",
                               result="hit" if cached else "miss")
            if cached:
                exec_state = self._place_state(snap.state, B)
                dstate = _transplant(exec_state, dstate)
                logits = (jax.device_put(snap.logits, shd.replicated(self.mesh))
                          if self.mesh is not None
                          else jnp.asarray(snap.logits))
        rem = n_full - cached
        if rem > 0:
            groups = _pow2_chunks(rem) if self.bucket_prompts else [rem]
            off = cached
            fin = None
            for g in groups:
                toks_g = prompts[:, off * self.seg_len:(off + g) * self.seg_len]
                if use_cache:
                    hidden, fin, bstates = self._forward(
                        toks_g, exec_state, enc_frames, capture=True)
                    blogits = boundary_logits(self.params, self.cfg, hidden)
                    for c in range(g):
                        end = (off + c + 1) * self.seg_len
                        self.prefix_cache.insert(
                            prompt_np[:end],
                            jax.tree_util.tree_map(lambda a, _c=c: a[_c],
                                                   bstates),
                            blogits[c], key=chain[off + c])
                else:
                    hidden, fin = self._forward(toks_g, exec_state,
                                                enc_frames, capture=False)
                logits = last_logits(self.params, self.cfg, hidden)
                exec_state = fin
                off += g
            dstate = _transplant(fin, dstate)
        tail = prompts[:, n_full * self.seg_len:]
        pos = 0                       # host-side segment position (no sync)
        if tail.shape[1] > 0:
            logits, dstate, pos = self._chunk(dstate, tail, pos)
        assert logits is not None, "empty prompt"
        return logits, dstate, pos, cached

    def _chunk(self, dstate, toks, pos: int):
        """Feed a multi-token chunk, flushing at ARMT segment boundaries.
        With bucket_prompts, each piece is the largest power of two that
        fits before the next boundary — O(log seg_len) compiled shapes.
        Implemented as a loop over ``_tail_pieces`` — the same
        decomposition the resumable PrefillPipeline runs one piece per
        ``advance()``, so the blocking and interleaved tail paths cannot
        drift."""
        logits = None
        pieces, end_pos = _tail_pieces(self, toks.shape[1], pos)
        for (t, take, flush) in pieces:
            logits, dstate = self._step(self.params, dstate,
                                        toks[:, t:t + take])
            if flush:
                with self.telemetry.span("flush_segment", "flush",
                                         take=take):
                    dstate = self._flush(self.params, dstate)
        return logits, dstate, end_pos

    # ------------------------------------------------------------------
    # Resumable prefill pipeline (interleaved admission, DESIGN.md §11)
    # ------------------------------------------------------------------

    def exec_apply(self):
        """The serving executor's block application pair
        ``(apply_block, grouped_apply)`` — the ONE source of truth for the
        numerics-critical (mode='segmented', ssm_method='assoc',
        grouped_impl) combination that the blocking prefill inherits
        through ``forward_hidden``'s defaults. ``prefill_step`` and the
        scheduler's ``fused_fns`` both build their diagonal stages from
        this, so the interleaved==blocking bit-identity cannot be broken
        by one copy drifting.

        Kernel lowering rides the same single source of truth: the fused
        grouped_apply's op calls resolve their implementation + tuning
        config through ``kernels/dispatch.py`` (honoring
        ``cfg.kernel_backend``, the autotune cache, and the per-backend
        heuristic table), so the scheduler's pooled launches and
        ``forward_hidden`` dispatch through one resolver — the
        ``kernel_dispatch_total{op,impl,backend,source}`` counters land
        in this engine's metrics registry (it defaults to the process
        registry the resolver writes to)."""
        from repro.models.blocks import make_apply_block
        from repro.models.grouped_blocks import resolve_grouped_apply
        apply = make_apply_block(self.cfg, mode="segmented",
                                 ssm_method="assoc")
        gapply = resolve_grouped_apply(self.cfg, self.grouped_impl,
                                       mode="segmented", ssm_method="assoc",
                                       remat=self.cfg.remat != "none")
        return apply, gapply

    def prefill_step(self, n_segments: int, batch: int, capture: bool,
                     n_groups: int):
        """The jitted resumable-prefill stepper for a diagonal stage of
        ``n_segments`` segments: ``step(params, xs, carry) -> carry``
        advancing ``n_groups`` anti-diagonal groups per call. Bucketed like
        ``_prefill`` (stages are power-of-two segment groups, so the cache
        holds O(log) programs per group budget), capture-aware (the carry's
        ``cap`` buffers feed the prefix cache exactly like the blocking
        path), and mesh-aware (slot-buffer/state constraints identical to
        ``_forward``'s diagonal run).

        The carry argument is DONATED on backends that honor donation —
        callers must never pass arrays a store still owns (see
        PrefillPipeline's fresh-buffer note)."""
        key = (n_segments, batch, capture, n_groups)
        if key in self._pipe_steps:
            return self._pipe_steps[key]
        layout = StackLayout.from_config(self.cfg)
        apply, gapply = self.exec_apply()
        buf_spec = self._slot_spec(batch)

        def step(params, xs, carry):
            exec_params = {"prelude": params["prelude"],
                           "pattern": params["pattern"]}
            # named_scope: XLA profiles show these ops under a stable label
            # that matches the scheduler's host spans (DESIGN.md §13)
            with jax.named_scope("serve.diag_stage"):
                return diag.pipeline_step(layout, exec_params, xs, carry,
                                          apply, n_groups=n_groups,
                                          buf_spec=buf_spec,
                                          grouped_apply=gapply,
                                          remat=self.cfg.remat != "none",
                                          retain_pos=self.seg_len - 1)

        donate = (2,) if jax.default_backend() != "cpu" else ()
        self._pipe_steps[key] = jax.jit(step, donate_argnums=donate)
        return self._pipe_steps[key]

    def _pool_step_body(self, n_segments: int, batch: int, capture: bool,
                        n_groups: int, n_pool: int):
        """The pooled-stepper body as a pure (un-jitted) function
        ``(params, xs_tuple, carry_tuple) -> carry_tuple`` over ``n_pool``
        same-signature admission carries — the single source of truth
        shared by the standalone jitted stepper (``pool_prefill_step``)
        and the fused global-grid launch (scheduler.fused_pool_fns).

        Stacking/unstacking happens INSIDE the traced body (tuples in,
        tuples out): one dispatch per round, and each member's output is
        its own buffer — unstacked members never alias each other, so
        handing them back to their pipelines is donation-safe."""
        layout = StackLayout.from_config(self.cfg)
        apply, gapply = self.exec_apply()
        mesh, stacked_axis = self.mesh, self.stacked_axis
        del capture                       # implied by the carry structure

        def body(params, xs_tup, carry_tup):
            with jax.named_scope("serve.pooled_diag_round"):
                exec_params = {"prelude": params["prelude"],
                               "pattern": params["pattern"]}
                xs_pool = jax.tree_util.tree_map(
                    lambda *ls: jnp.stack(ls), *xs_tup)
                carry_pool = jax.tree_util.tree_map(
                    lambda *ls: jnp.stack(ls), *carry_tup)
                pool_spec = None
                if mesh is not None:
                    pool_spec = shd.pool_carry_specs(
                        carry_pool, mesh, layout.n_layers, batch,
                        stacked_axis=stacked_axis)
                carry_pool = diag.pipeline_step_pool(
                    layout, exec_params, xs_pool, carry_pool, apply,
                    n_groups=n_groups, grouped_apply=gapply,
                    pool_spec=pool_spec,
                    remat=self.cfg.remat != "none",
                    retain_pos=self.seg_len - 1)
                return tuple(
                    jax.tree_util.tree_map(lambda a, _i=i: a[_i], carry_pool)
                    for i in range(n_pool))

        return body

    def pool_prefill_step(self, n_segments: int, batch: int, capture: bool,
                          n_groups: int, n_pool: int):
        """The jitted pooled stepper (DESIGN.md §12): one launch advances
        ``n_pool`` same-signature admission carries by ``n_groups`` groups
        each. Pool sizes are pow2-bucketed by the caller (``pool_pack``),
        so the cache holds O(log N) programs per (S, capture, k) on top of
        the single-carry stepper's O(log) stage buckets.

        The carry tuple is DONATED on backends that honor donation — every
        entry (including pad members) must be fresh-buffered and pairwise
        non-aliased (see diag.pipeline_pool_pad)."""
        key = (n_segments, batch, capture, n_groups, n_pool)
        if key in self._pool_steps:
            return self._pool_steps[key]
        body = self._pool_step_body(n_segments, batch, capture, n_groups,
                                    n_pool)
        donate = (2,) if jax.default_backend() != "cpu" else ()
        self._pool_steps[key] = jax.jit(body, donate_argnums=donate)
        return self._pool_steps[key]

    def pool_pack(self, n_segments: int, group):
        """Pad a same-signature admission group — ``[(pipe, xs, carry),
        ...]`` — up to its pow2 pool bucket: returns ``(n_pool, xs_tuple,
        carry_tuple)`` with fresh zero no-op pad members (cursor parked
        past the grid, diag.pipeline_pool_pad)."""
        n = len(group)
        n_pool = 1 << (n - 1).bit_length() if n > 1 else 1
        xs_t = tuple(x for _, x, _ in group)
        carry_t = tuple(c for _, _, c in group)
        n_steps = n_diagonal_groups(n_segments, self._n_layers)
        for _ in range(n_pool - n):
            px, pc = diag.pipeline_pool_pad(xs_t[0], carry_t[0], n_steps)
            xs_t += (px,)
            carry_t += (pc,)
        return n_pool, xs_t, carry_t

    def pool_prefill_step_run(self, n_segments: int, capture: bool,
                              n_groups: int, group):
        """Advance every member of ``group`` (same (S, capture, k)
        signature, B=1 admissions) by ``n_groups`` diagonal groups in ONE
        jitted launch; returns the new carries in member order. The input
        carries are donated — callers must treat them as consumed and keep
        only the returned ones (AdmissionPool does)."""
        n_pool, xs_t, carry_t = self.pool_pack(n_segments, group)
        step = self.pool_prefill_step(n_segments, 1, capture, n_groups,
                                      n_pool)
        with self._mesh_ctx():
            out = step(self.params, xs_t, carry_t)
        return list(out[:len(group)])

    # ------------------------------------------------------------------
    # Admission memory accounting (DESIGN.md §15)
    # ------------------------------------------------------------------

    def prefill_activation_bytes(self, n_segments: int, batch: int = 1, *,
                                 stream: bool = True) -> int:
        """Host-side analytic estimate of the device buffers one diagonal
        admission of ``n_segments`` segments holds while suspended: the
        read-only drain-padded ``xs [S+L-1]``, the slot buffer ``[L]``, and
        the output carry — the rolling ``win [min(L,S)]`` + ``brow`` pair
        in stream mode, the full ``ys [S]`` otherwise (all in units of one
        ``[B, T, D]`` segment). Pure arithmetic (no compile, no sync): the
        scheduler's byte-budget admission check runs this per request. The
        compiled-program ground truth is ``prefill_memory_stats``."""
        cfg = self.cfg
        M = cfg.armt.num_mem_tokens if cfg.armt is not None else 0
        T = self.seg_len + M
        item = jnp.dtype(self.params["embed"].dtype).itemsize
        seg = batch * T * cfg.d_model * item
        L = self._n_layers
        S = n_segments
        total = (S + L - 1) * seg + L * seg                  # xs + buf
        if stream:
            total += min(L, S) * seg + S * batch * cfg.d_model * item
        else:
            total += S * seg                                 # full ys
        return total

    def prefill_memory_stats(self, n_segments: int, batch: int = 1, *,
                             capture: bool = False, stream: bool = False,
                             n_groups: int = 4) -> Dict:
        """AOT-compile the resumable prefill stepper for this signature
        (abstract inputs — nothing runs) and return its
        ``compiled.memory_analysis()`` byte counts:
        ``{argument,output,temp,peak}_bytes`` (peak falls back to
        argument+output+temp where the backend reports no peak — the
        launch/dryrun.py pattern). Cached per signature; publishes
        ``memory.temp_bytes`` / ``memory.peak_bytes`` gauges to the
        engine's metrics registry so the serve stack's memory trajectory
        is scraped like any other metric (DESIGN.md §15)."""
        key = (n_segments, batch, capture, stream, n_groups)
        if key in self._mem_stats:
            return self._mem_stats[key]
        cfg = self.cfg
        layout = StackLayout.from_config(cfg)
        dtype = self.params["embed"].dtype
        M = cfg.armt.num_mem_tokens if cfg.armt is not None else 0
        T = self.seg_len + M
        state0 = init_state(cfg, batch, "segmented", dtype)
        x_abs = jax.ShapeDtypeStruct((n_segments, batch, T, cfg.d_model),
                                     dtype)
        xs_abs, carry_abs = jax.eval_shape(
            lambda x: diag.pipeline_init(layout, state0, x,
                                         capture_states=capture,
                                         stream_ys=stream), x_abs)
        step = self.prefill_step(n_segments, batch, capture, n_groups)
        with self._mesh_ctx():
            compiled = step.lower(self.params, xs_abs, carry_abs).compile()
        stats = {"argument_bytes": None, "output_bytes": None,
                 "temp_bytes": None, "peak_bytes": None}
        try:
            ma = compiled.memory_analysis()
            arg = getattr(ma, "argument_size_in_bytes", None)
            out = getattr(ma, "output_size_in_bytes", None)
            temp = getattr(ma, "temp_size_in_bytes", None)
            peak = getattr(ma, "peak_memory_in_bytes", None)
            if peak is None and None not in (arg, out, temp):
                peak = arg + out + temp
            stats = {"argument_bytes": arg, "output_bytes": out,
                     "temp_bytes": temp, "peak_bytes": peak}
        except Exception:       # backend without memory_analysis support
            pass
        reg = self.telemetry.registry
        if reg is not None:
            labels = dict(n_segments=str(n_segments),
                          stream="on" if stream else "off")
            if stats["temp_bytes"] is not None:
                reg.set_gauge("memory.temp_bytes", stats["temp_bytes"],
                              **labels)
            if stats["peak_bytes"] is not None:
                reg.set_gauge("memory.peak_bytes", stats["peak_bytes"],
                              **labels)
        self._mem_stats[key] = stats
        return stats

    def start_prefill(self, prompts: jax.Array, *,
                      groups_per_call: Optional[int] = 4,
                      session_entry=None,
                      stream: bool = False,
                      max_stage_segments: Optional[int] = None
                      ) -> "PrefillPipeline":
        """Begin a *resumable* admission (DESIGN.md §11): returns a
        PrefillPipeline equivalent to ``_prefill(prompts)`` (or, with
        ``session_entry``, to the session-resume chunk feed) whose
        ``advance()`` runs one bounded unit of work — ``groups_per_call``
        anti-diagonal groups of the active diagonal stage, or one tail
        chunk piece — so a scheduler can interleave decode chunks between
        calls instead of blocking on the whole prefill.

        ``stream``: bounded-memory admission (DESIGN.md §15) — the diagonal
        stages carry the rolling ``win``/``brow`` pair instead of the full
        ``ys [S, B, T, D]``, so the per-admission activation footprint is
        flat in prompt length. Identical results (last-position logits,
        boundary states, final recurrent state) — the prefix-cache hidden
        states come from the same capture buffers either way.

        ``max_stage_segments``: cap each diagonal stage at this many
        segments — oversized prompts then chunk through multiple resumable
        stages (the recurrent state chains across stages exactly like the
        blocking path's pow2 groups), bounding even the read-only ``xs``
        buffer per stage. The scheduler's byte-budget admission sets both
        knobs together (overflow-aware admission, DESIGN.md §15)."""
        return PrefillPipeline(self, prompts,
                               groups_per_call=groups_per_call,
                               session_entry=session_entry,
                               stream=stream,
                               max_stage_segments=max_stage_segments)

    # ------------------------------------------------------------------
    # On-device decode loop
    # ------------------------------------------------------------------

    def _decode_loop(self, max_new: int, greedy: bool, top_k: int):
        """Build (and cache) the jitted whole-decode loop: a lax.scan over
        steps that samples, steps, and flushes at segment boundaries via
        lax.cond — no host branching, no per-token device->host transfer.
        The decode state is donated to the loop (freely overwritten in
        place on backends that support donation) and the final carry comes
        back out, so a session store can persist it without re-running
        anything. Note the last sampled token is never fed through the
        model — the returned state has consumed max_new - 1 of the emitted
        tokens; the last one is the session's `pending` token."""
        key_ = (max_new, greedy, top_k)
        if key_ in self._loops:
            return self._loops[key_]
        cfg, serve_mode, seg_len = self.cfg, self.serve_mode, self.seg_len
        armt_on = serve_mode == "armt" and cfg.armt is not None

        def loop(params, dstate, logits0, temp, rng):
            def sample(logits, k):
                # `temp` stays a traced scalar so changing the temperature
                # value never recompiles; greedy vs sampling is a different
                # graph (keyed in self._loops)
                if greedy:
                    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
                scaled = logits / temp
                if top_k > 0:
                    kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
                    scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
                return jax.random.categorical(k, scaled, -1).astype(jnp.int32)

            def body(carry, key_t):
                state, tok = carry
                logits, state = decode_step(params, cfg, state, tok,
                                            serve_mode=serve_mode)
                if armt_on:
                    state = jax.lax.cond(
                        state["pos"] >= seg_len,
                        lambda s: flush_segment(params, cfg, s),
                        lambda s: s, state)
                nxt = sample(logits, key_t)
                return (state, nxt), nxt

            # token 0 comes from the prefill logits; the scan emits the
            # max_new-1 stepped samples, so the last emitted token is never
            # fed through a wasted forward
            with jax.named_scope("serve.decode_loop"):
                keys = jax.random.split(rng, max_new)
                tok0 = sample(logits0, keys[0])
                (fstate, _), toks = jax.lax.scan(body, (dstate, tok0),
                                                 keys[1:])
                toks = jnp.concatenate([tok0[None], toks],
                                       axis=0).T  # [B, max_new]
                return toks, fstate

        # donation is a no-op (with a warning) on CPU — only request it where
        # the backend honors it
        donate = (1,) if jax.default_backend() != "cpu" else ()
        self._loops[key_] = jax.jit(loop, donate_argnums=donate)
        return self._loops[key_]

    def generate(self, prompts: jax.Array, max_new: int, *,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 enc_frames=None,
                 session_id: Optional[str] = None) -> GenerationResult:
        """Prefill + decode max_new tokens. temperature<=0: greedy (the
        default, deterministic); otherwise temperature/top-k sampling with
        an on-device PRNG. One device->host transfer for the whole call.

        session_id: persist the end-of-generation state in the engine's
        session store and, when a state for this id already exists, resume
        from it — the prompt is then *this turn's new tokens only* and the
        conversation history is never recomputed."""
        B, P = prompts.shape
        entry = None
        if session_id is not None:
            if self.session_store is None:
                raise ValueError("session_id given but the engine has no "
                                 "session_store")
            if B != 1:
                raise ValueError("sessions are per-conversation: B must be 1")
            entry = self.session_store.get(session_id)   # None on first turn
        if self.serve_mode == "cache":
            base = (entry.pos + len(entry.pending)) if entry is not None else 0
            if base + P + max_new > self.max_len:
                # the KV write offset would clamp at the cache end and
                # silently corrupt logits — refuse instead
                raise ValueError(
                    f"prompt_len {P} + max_new {max_new} (+{base} session "
                    f"tokens) exceeds max_len {self.max_len} of the KV cache")
        t0 = time.perf_counter()
        cached = 0
        tel = self.telemetry
        if entry is not None:
            # scatter-on-restore: session blobs are mesh-shape-agnostic
            # (gathered to host by the store when sharded) — commit them to
            # *this* engine's shardings, whatever mesh the blob came from
            with tel.span("session_restore", "session", session=session_id):
                restored = self._place_state(
                    {"prelude": entry.state["prelude"],
                     "pattern": entry.state["pattern"]}, 1)
                dstate = {**restored,
                          "pos": jnp.asarray(entry.pos, jnp.int32)}
                toks_in = np.concatenate(
                    [entry.pending, np.asarray(prompts[0], np.int32)])
                logits, dstate, _pos = self._chunk(
                    dstate, jnp.asarray(toks_in[None]), entry.pos)
        else:
            with tel.span("prefill", "prefill", prompt_len=P, batch=B):
                logits, dstate, _pos, cached = self._prefill(
                    prompts, enc_frames=enc_frames)
        jax.block_until_ready(logits)
        t_first = time.perf_counter()
        with tel.span("decode", "decode", max_new=max_new):
            loop = self._decode_loop(max_new, temperature <= 0.0, top_k)
            toks, fstate = loop(self.params, dstate, logits,
                                jnp.float32(max(temperature, 1e-6)),
                                jax.random.PRNGKey(seed))
            toks = np.asarray(toks)
        t_end = time.perf_counter()
        tel.observe("generate_ttft_s", t_first - t0)
        tel.observe("generate_decode_tok_s",
                    max_new / max(t_end - t_first, 1e-9))
        if session_id is not None:
            # the loop never feeds the last sampled token — it becomes the
            # resume's `pending` prefix (see _decode_loop)
            history = np.concatenate([
                entry.tokens if entry is not None else np.empty(0, np.int32),
                np.asarray(prompts[0], np.int32), toks[0]]).astype(np.int32)
            self.session_store.put(
                session_id,
                state={"prelude": fstate["prelude"],
                       "pattern": fstate["pattern"]},
                pos=int(np.asarray(fstate["pos"]).reshape(-1)[0]),
                pending=toks[0, -1:], tokens=history)
        return GenerationResult(
            toks, P // self.seg_len, self.schedule,
            ttft_s=t_first - t0,
            tok_s=max_new / max(t_end - t_first, 1e-9),
            cached_segments=cached, session_id=session_id,
            resumed=entry is not None,
            metrics=(self.metrics_snapshot()
                     if tel.registry is not None else None))

    # ------------------------------------------------------------------
    # Continuous batching
    # ------------------------------------------------------------------

    def serve(self, requests: Iterable, *, n_slots: int = 4,
              chunk: int = 8, max_queue: Optional[int] = None,
              prefill_groups_per_chunk: int = 4,
              fused_admission: bool = False,
              max_concurrent_admissions: Optional[int] = None,
              admission_fairness: str = "round_robin",
              admission_byte_budget: Optional[int] = None) -> Iterator:
        """Continuous-batching streaming front door: admit `Request`s into a
        fixed pool of decode slots and yield `StreamEvent`s as tokens are
        produced. Rejections (queue-full, invalid request, evicted session)
        come back as structured `RequestError` events on the same stream —
        the iterator never raises mid-serve for a bad request (see
        serve/scheduler.py for the slot-state invariants).

        prefill_groups_per_chunk: admission fairness knob (DESIGN.md §11) —
        the new request's prefill advances this many diagonal groups per
        decode chunk instead of blocking every slot for its whole prompt;
        0 restores the legacy blocking admission. fused_admission: run the
        admission's diagonal groups inside the same jitted launch as the
        decode chunk (one dispatch per interval).

        max_concurrent_admissions: cap on interleaved admissions in flight
        at once (DESIGN.md §12); None (default) bounds the pool only by
        free slots, 1 restores the PR 5 single-admission behavior.
        admission_fairness: 'round_robin' (default — every in-flight
        admission advances k groups per round, same-signature carries
        pooled into one launch) or 'oldest_first' (head-of-line).

        admission_byte_budget: overflow-aware admission (DESIGN.md §15) —
        prompts whose full-``ys`` prefill would hold more than this many
        activation bytes are admitted through the streaming carry with
        byte-bounded stages instead of being rejected or ballooning
        memory; None (default) disables the check."""
        from repro.serve.scheduler import ContinuousScheduler
        sched = ContinuousScheduler(
            self, n_slots=n_slots, chunk=chunk, max_queue=max_queue,
            prefill_groups_per_chunk=prefill_groups_per_chunk,
            fused_admission=fused_admission,
            max_concurrent_admissions=max_concurrent_admissions,
            admission_fairness=admission_fairness,
            admission_byte_budget=admission_byte_budget)
        return sched.run(requests)


def _tail_pieces(engine: ServeEngine, total: int, pos: int):
    """Host-side decomposition of a token-chunk feed into bounded
    ``decode_step`` pieces: [(start, take, flush_after), ...] plus the
    final in-segment position — ``pos`` is tracked host-side because
    decode_step advances the device-side ``dstate['pos']`` by exactly the
    tokens fed, so the two never diverge and no per-piece device->host
    readback exists. The single source of truth for tail bucketing:
    ``ServeEngine._chunk`` runs all pieces blocking, the resumable
    PrefillPipeline runs one per ``advance()`` — token-identical by
    construction because both consume this same decomposition."""
    pieces = []
    t = 0
    while t < total:
        room = (engine.seg_len - pos if engine.serve_mode == "armt"
                else total - t)
        take = min(room, total - t)
        if engine.bucket_prompts:
            take = 1 << (take.bit_length() - 1)
        pos += take
        flush = (engine.serve_mode == "armt" and engine.cfg.armt is not None
                 and pos >= engine.seg_len)
        pieces.append((t, take, flush))
        if flush:
            pos = 0
        t += take
    return pieces, pos


class PrefillPipeline:
    """A suspended/resumable admission (DESIGN.md §11).

    ``ServeEngine._prefill`` decomposed into bounded work units the host
    drives one ``advance()`` at a time:

      * one *diagonal stage* per power-of-two segment group (the same
        bucketing as ``_prefill``) — each ``advance()`` runs one jitted
        ``engine.prefill_step`` dispatch of ``groups_per_call``
        anti-diagonal groups on the stage's carry;
      * one *tail piece* per bounded ``decode_step`` chunk (the same
        decomposition as ``_chunk`` — also the whole pipeline for a
        session resume, which replays pending + new-turn tokens).

    Token-identical (greedy) to the blocking path by construction: the
    diagonal stages share the one-shot executor's step body bit for bit
    (core/diagonal.py), tail pieces reuse the engine's jitted ``_step`` /
    ``_flush``, prefix-cache matching/insertion and the boundary-logits
    math run the exact same host code on the same arrays.

    Carry freshness: the jitted stepper *donates* its carry, so every
    restored leaf entering it (prefix-cache snapshot, session blob) is
    routed through ``engine._place_state`` — the same fresh-buffer
    guarantee the decode loop got for store blobs. Without the copy, the
    first ``advance()`` after a cache hit would delete the store's own
    arrays out from under it (donation-aliasing; regression-tested in
    tests/test_serve_interleave.py). For the same reason the carry never
    aliases the scheduler's pool: a decode chunk donating the pool between
    ``advance()`` calls cannot invalidate a suspended carry.
    """

    def __init__(self, engine: ServeEngine, prompts, *,
                 groups_per_call: Optional[int] = 4, session_entry=None,
                 stream: bool = False,
                 max_stage_segments: Optional[int] = None):
        self.engine = engine
        self._stream = bool(stream)
        if max_stage_segments is not None and max_stage_segments < 1:
            raise ValueError(
                f"max_stage_segments must be >= 1, got {max_stage_segments}")
        self._max_stage = max_stage_segments
        # None: each advance() runs its whole diagonal stage in one jitted
        # call (blocking semantics through the resumable machinery — the
        # fair baseline the bench compares against, free of the legacy
        # path's per-admission retracing)
        if groups_per_call is not None and groups_per_call < 1:
            raise ValueError(
                f"groups_per_call must be >= 1 or None (whole stage per "
                f"advance), got {groups_per_call}; the scheduler's "
                "'0 = legacy blocking' knob never constructs a pipeline")
        self.groups_per_call = (None if groups_per_call is None
                                else int(groups_per_call))
        prompts = jnp.asarray(prompts)
        assert prompts.ndim == 2, prompts.shape
        self.prompts = prompts
        B, P = prompts.shape
        self.B = B
        cfg = engine.cfg
        dtype = engine.params["embed"].dtype
        self.cached = 0
        self._logits = None
        self._pos = 0
        self._done = False
        self._stage = 0
        self._stages = []            # ("diag", off, g) | ("tail", t, take, fl)
        self._carry = None
        self._xs = None
        self._exec_state = None
        self._use_cache = False
        self._prompt_np = None
        self._chain = None

        if session_entry is not None:
            # O(new turn) resume: the restored blob goes through
            # _place_state (fresh buffers — see the class docstring) and is
            # then consumed piecewise by tail chunks only
            if B != 1:
                raise ValueError("sessions are per-conversation: B must be 1")
            with engine.telemetry.span("session_restore", "session"):
                restored = engine._place_state(
                    {"prelude": session_entry.state["prelude"],
                     "pattern": session_entry.state["pattern"]}, B)
            self._dstate = {**restored,
                            "pos": jnp.asarray(session_entry.pos, jnp.int32)}
            toks_in = np.concatenate(
                [session_entry.pending, np.asarray(prompts[0], np.int32)])
            self._tail = jnp.asarray(toks_in[None])
            self._pos = int(session_entry.pos)
            pieces, _ = _tail_pieces(engine, int(toks_in.shape[0]), self._pos)
            self._stages = [("tail",) + p for p in pieces]
            return

        # --- full-prefill path: mirror _prefill's host prologue ----------
        dstate = decode_state_init(cfg, B, serve_mode=engine.serve_mode,
                                   max_len=engine.max_len, dtype=dtype)
        if engine.mesh is not None:
            dstate = jax.device_put(dstate, engine.state_sharding(B))
        self._dstate = dstate
        n_full = P // engine.seg_len if engine.serve_mode == "armt" else 0
        use_cache = (engine.prefix_cache is not None and B == 1
                     and n_full > 0)
        if use_cache:
            from repro.serve.state_store import prefix_hash_chain
            self._prompt_np = np.asarray(prompts[0], np.int32)
            self._chain = prefix_hash_chain(self._prompt_np, engine.seg_len)
            with engine.telemetry.span("prefix_probe", "cache",
                                       n_segments=n_full):
                self.cached, snap = engine.prefix_cache.match(
                    self._prompt_np, chain=self._chain)
            engine.telemetry.inc("prefix_probe_total",
                                 result="hit" if self.cached else "miss")
            if self.cached:
                # fresh buffers (the stepper donates this into its carry)
                self._exec_state = engine._place_state(snap.state, B)
                self._logits = (
                    jax.device_put(snap.logits, shd.replicated(engine.mesh))
                    if engine.mesh is not None else jnp.asarray(snap.logits))
                if self.cached == n_full:
                    # exact full-segment hit: nothing left for the executor —
                    # transplant straight into the decode state for the tail
                    self._dstate = _transplant(self._exec_state, self._dstate)
        self._use_cache = use_cache
        rem = n_full - self.cached
        if self._max_stage is not None and rem > self._max_stage:
            # byte-budget chunking (DESIGN.md §15): as many largest-pow2-
            # under-cap stages as fit, then the pow2 decomposition of the
            # remainder — every stage size stays a power of two (bounded
            # compile count) and <= the cap (bounded xs/carry bytes); the
            # recurrent state chains across stages like any staged prefill
            cap = 1 << (self._max_stage.bit_length() - 1)
            groups = [cap] * (rem // cap) + _pow2_chunks(rem % cap)
        else:
            groups = (_pow2_chunks(rem) if engine.bucket_prompts
                      else ([rem] if rem else []))
        off = self.cached
        for g in groups:
            if engine.schedule != "diagonal":
                raise ValueError(
                    "start_prefill needs the diagonal schedule for segment "
                    f"stages (engine.schedule={engine.schedule!r}); use the "
                    "blocking _prefill instead")
            self._stages.append(("diag", off, g))
            off += g
        tail = prompts[:, n_full * engine.seg_len:]
        if tail.shape[1] > 0:
            self._tail = tail
            pieces, _ = _tail_pieces(engine, int(tail.shape[1]), 0)
            self._stages += [("tail",) + p for p in pieces]
        if not self._stages:
            assert self._logits is not None, "empty prompt"
            self._done = True

    # -- progress ----------------------------------------------------------

    @property
    def done(self) -> bool:
        return self._done

    def result(self):
        """(next_token_logits, decode_state, in-segment pos, cached) — the
        blocking ``_prefill`` quadruple; valid once ``done``."""
        assert self._done, "pipeline not finished — keep calling advance()"
        return self._logits, self._dstate, self._pos, self.cached

    # -- diagonal stages ---------------------------------------------------

    def _begin_diag(self, off: int, g: int) -> None:
        eng = self.engine
        cfg = eng.cfg
        seg = eng.seg_len
        toks_g = self.prompts[:, off * seg:(off + g) * seg]
        with_mem = cfg.armt is not None and cfg.armt.num_mem_tokens > 0
        x = embed_segments(eng.params, cfg, toks_g, seg, with_mem)
        layout = StackLayout.from_config(cfg)
        state0 = self._exec_state
        if state0 is None:
            state0 = init_state(cfg, self.B, "segmented",
                                eng.params["embed"].dtype)
        xs, carry = diag.pipeline_init(layout, state0, x,
                                       capture_states=self._use_cache,
                                       stream_ys=self._stream)
        if eng.mesh is not None:
            specs = shd.pipeline_carry_specs(
                carry, eng.mesh, layout.n_layers, self.B,
                stacked_axis=eng.stacked_axis)
            xs = jax.device_put(xs, specs["xs"])
            carry = jax.device_put(carry, {k: specs[k] for k in carry})
        self._xs, self._carry = xs, carry
        self._groups_done = 0
        self._n_steps = n_diagonal_groups(g, layout.n_layers)
        self._exec_state = None      # consumed into the (donated) carry

    def _finish_diag(self, off: int, g: int) -> None:
        eng = self.engine
        cfg = eng.cfg
        layout = StackLayout.from_config(cfg)
        ys, fin, capd = diag.pipeline_finalize(layout, self._carry)
        with_mem = cfg.armt is not None and cfg.armt.num_mem_tokens > 0
        if self._stream:
            # Streaming carry (DESIGN.md §15): `brow [S, B, D]` holds
            # exactly the retained row the consumers below read —
            # boundary_logits and last_logits both slice position
            # ``[:, :, -1]`` of the seg_len-trimmed hidden, which is the
            # ``retain_pos = seg_len - 1`` row the stepper kept. Lifting
            # brow to [S, B, 1, D] makes both functions read it unchanged,
            # so the logits math is the same host code on the same values.
            hidden = ys["brow"][:, :, None, :]
        else:
            hidden = ys[:, :, :eng.seg_len] if with_mem else ys
        if self._use_cache:
            blogits = boundary_logits(eng.params, cfg, hidden)
            for c in range(g):
                end = (off + c + 1) * eng.seg_len
                eng.prefix_cache.insert(
                    self._prompt_np[:end],
                    jax.tree_util.tree_map(lambda a, _c=c: a[_c], capd),
                    blogits[c], key=self._chain[off + c])
        self._logits = last_logits(eng.params, cfg, hidden)
        self._exec_state = fin
        self._carry = self._xs = None
        self._stage += 1
        if not any(s[0] == "diag" for s in self._stages[self._stage:]):
            self._dstate = _transplant(fin, self._dstate)

    def active_diag(self):
        """(n_segments, capture, xs, carry) of the in-flight diagonal stage
        (beginning it if needed), or None when the next unit is a tail
        piece / the pipeline is done — the scheduler's fused admission mode
        feeds these through its combined decode+prefill launch."""
        if self._done or self._stage >= len(self._stages):
            return None
        st = self._stages[self._stage]
        if st[0] != "diag":
            return None
        if self._carry is None:
            self._begin_diag(st[1], st[2])
        return st[2], self._use_cache, self._xs, self._carry

    def _groups_per_advance(self) -> int:
        return self.groups_per_call or self._n_steps

    def _advance_diag(self, new_carry=None) -> None:
        st = self._stages[self._stage]
        _, off, g = st
        if self._carry is None:
            self._begin_diag(off, g)
        k = self._groups_per_advance()
        if new_carry is None:
            step = self.engine.prefill_step(g, self.B, self._use_cache, k)
            with self.engine._mesh_ctx():
                self._carry = step(self.engine.params, self._xs, self._carry)
        else:
            self._carry = new_carry
        self._groups_done += k
        if self._groups_done >= self._n_steps:
            self._finish_diag(off, g)

    def apply_diag_result(self, carry) -> bool:
        """Accept the carry advanced ``groups_per_call`` groups by a fused
        scheduler launch; returns ``done`` like ``advance()``."""
        self._advance_diag(new_carry=carry)
        if self._stage >= len(self._stages):
            self._finish()
        return self._done

    # -- tail pieces -------------------------------------------------------

    def _run_tail_piece(self, stage) -> None:
        _, t, take, flush = stage
        eng = self.engine
        self._logits, self._dstate = eng._step(eng.params, self._dstate,
                                               self._tail[:, t:t + take])
        self._pos += take
        if flush:
            with eng.telemetry.span("flush_segment", "flush", take=take):
                self._dstate = eng._flush(eng.params, self._dstate)
            self._pos = 0
        self._stage += 1

    # -- driver ------------------------------------------------------------

    def _finish(self) -> None:
        assert self._logits is not None, "empty prompt"
        self._done = True

    def advance(self) -> bool:
        """Run one bounded unit (k diagonal groups or one tail piece);
        returns True when the admission is complete (``result()`` ready)."""
        if self._done:
            return True
        st = self._stages[self._stage]
        if st[0] == "diag":
            self._advance_diag()
        else:
            self._run_tail_piece(st)
        if self._stage >= len(self._stages):
            self._finish()
        return self._done


class AdmissionPool:
    """N concurrent resumable admissions advanced together (DESIGN.md §12).

    Generalizes §11's single suspended PrefillPipeline to a FIFO pool:
    each fairness round every member advances one bounded unit — its
    ``groups_per_call`` anti-diagonal groups or one tail piece — and the
    members whose active unit is a diagonal stage of the SAME
    (n_segments, capture, k) signature ride ONE pooled jitted launch
    (``ServeEngine.pool_prefill_step``): their carries stack on a leading
    pool axis, per-carry cursors keep heterogeneous progress exact (masked
    overshoot), and the pool size pads to a power of two so the compile
    count stays O(log N) per signature. Per-member host state — prefix
    cache match/insert, session resume, tail bucketing, boundary logits —
    lives in each PrefillPipeline unchanged: pooling batches DEVICE work
    only, so every member is token-identical to its own one-at-a-time
    pipeline by construction.

    Donation safety: the pooled stepper donates the carry tuple, so member
    carries are consumed by ``advance_round`` and replaced via
    ``apply_diag_result`` — nothing else may hold the old arrays (the same
    contract the single-carry stepper already imposes; pads are fresh
    zeros, never aliases)."""

    def __init__(self, engine: ServeEngine):
        self.engine = engine
        self.members: List[PrefillPipeline] = []      # FIFO admission order

    def __len__(self) -> int:
        return len(self.members)

    def add(self, pipe: PrefillPipeline) -> None:
        self.members.append(pipe)

    def grid_cells_remaining(self) -> int:
        """Unexecuted (segment, layer) cells across every member's
        remaining diagonal stages — the pool's share of the global grid.
        Host-side cursors only (never syncs a device carry)."""
        from repro.core.schedule import pool_cells_remaining
        L = self.engine._n_layers
        total = 0
        for pipe in self.members:
            steps, segs = [], []
            for idx, st in enumerate(pipe._stages[pipe._stage:]):
                if st[0] != "diag":
                    continue
                segs.append(st[2])
                steps.append(pipe._groups_done
                             if idx == 0 and pipe._carry is not None else 0)
            total += pool_cells_remaining(steps, segs, L)
        return total

    def diag_buckets(self):
        """Group members whose next unit is a diagonal stage by pooled-
        launch signature: ``{(n_segments, capture, stream, k): [(pipe, xs,
        carry), ...]}`` in member (FIFO) order. ``stream`` keeps
        bounded-memory (win/brow) carries out of full-``ys`` pools — the
        carry structures differ, so they cannot stack. Members at a tail
        piece (or done) are absent — they advance individually."""
        buckets: Dict = {}
        for pipe in self.members:
            ad = pipe.active_diag()
            if ad is None:
                continue
            g, capture, xs, carry = ad
            sig = (g, capture, pipe._stream, pipe._groups_per_advance())
            buckets.setdefault(sig, []).append((pipe, xs, carry))
        return buckets

    def advance_round(self, *, already_advanced=()):
        """One fairness round: every member advances one bounded unit.
        Same-signature diagonal groups of >= 2 members ride one pooled
        launch; singletons and tail pieces advance individually (the PR 5
        single-carry programs). ``already_advanced``: ids of pipes a fused
        scheduler launch advanced this round — they are skipped here.
        Returns the members that completed, FIFO, removed from the pool."""
        advanced = set(already_advanced)
        for sig, group in self.diag_buckets().items():
            group = [g for g in group if id(g[0]) not in advanced]
            if len(group) < 2:
                continue
            g_segs, capture, _stream, k = sig
            carries = self.engine.pool_prefill_step_run(
                g_segs, capture, k, group)
            for (pipe, _, _), c in zip(group, carries):
                pipe.apply_diag_result(c)
                advanced.add(id(pipe))
        done = []
        for pipe in list(self.members):
            if id(pipe) in advanced:
                if pipe.done:
                    done.append(pipe)
            elif pipe.advance():
                done.append(pipe)
        for pipe in done:
            self.members.remove(pipe)
        return done

    def advance_oldest(self):
        """Head-of-line fairness (``admission_fairness='oldest_first'``):
        only the oldest member advances this round — the reference policy
        the round-robin default is contrasted against in tests/bench."""
        pipe = self.members[0]
        if pipe.advance():
            self.members.remove(pipe)
            return [pipe]
        return []


