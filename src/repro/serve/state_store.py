"""Memory-state store: segment-granular prefix caching + session resume.

In an RMT the recurrent memory at a segment boundary — per-layer (A, z)
associative matrices and SSM (h, conv) carries — is a *constant-size*
summary of the entire prefix (PAPER.md; Bulatov et al. 2022). That makes
prefix caching possible at segment granularity by snapshotting kilobytes of
state per cached prefix, where a KV-cache prefix store needs gigabytes, and
it sidesteps the state-recomputation cost other recurrent long-context
models pay on every turn.

Three pieces (DESIGN.md §9):

* ``SegmentSnapshot`` — the captured boundary state: the recurrent leaves
  (core.memory.RECURRENT_KEYS), the exact token-id prefix it summarizes,
  and the boundary's last-position logits (so an exact full-prefix hit
  needs no forward at all). At a boundary the in-segment position is 0 and
  the segment KV cache is empty by construction, so neither is stored.

* ``PrefixCache`` — content-addressed by a *rolling hash* over segment
  token ids: digest(c) = H(digest(c-1) || tokens[c-th segment]), so all
  boundary keys of a P-token prompt cost one O(P) pass. Lookup walks
  boundaries longest-first and — hash collisions being cheap to fake and
  catastrophic to serve — always verifies the full token ids of a
  candidate before returning it.

* ``SessionStore`` — multi-turn chat state: the *full* decode state of a
  finished generation (recurrent memory + current-segment KV cache +
  in-segment position) keyed by ``session_id``, plus any emitted-but-not-
  yet-consumed tokens (``pending``) and the token history. The next turn
  of the session resumes by transplanting the stored state and feeding
  only ``pending + new_prompt`` — O(new turn), not O(history).

Both stores share an LRU byte-budget evictor. Evicted payloads spill to
host disk through ``checkpoint.manager.CheckpointManager`` named blobs when
a spill directory is configured (restored transparently on the next hit);
without spill, an evicted prefix is simply a future cache miss, while an
evicted session becomes a tombstone — resuming it raises ``SessionEvicted``
rather than silently serving a turn with amnesia.

Snapshots from a *single-device* engine are stored as whatever arrays the
caller hands over (device arrays straight out of the jitted prefill/drain —
nothing forces a device->host sync at capture time; byte accounting uses
shape/dtype only). Arrays that are *sharded across devices* are gathered to
host numpy at ``put`` time (``gather_to_host``) — the mesh-native serving
boundary (DESIGN.md §10): stored blobs carry no mesh shape, so a snapshot
captured on a 2x4 mesh restores on a single device and vice versa; the
engine re-scatters restored leaves to its own decode-state shardings
(scatter-on-restore). Single-device arrays additionally cross to host when
an entry is spilled to disk.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["SegmentSnapshot", "SessionEntry", "SessionEvicted",
           "PrefixCache", "SessionStore", "prefix_hash_chain",
           "tree_nbytes", "gather_to_host"]


def tree_nbytes(tree: Any) -> int:
    """Total payload bytes of a pytree of (np or jax) arrays — from
    shape/dtype metadata only, no device sync."""
    import jax
    return sum(int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize
               for a in jax.tree_util.tree_leaves(tree))


def gather_to_host(tree: Any) -> Any:
    """Gather-on-capture boundary for mesh-native serving (DESIGN.md §10):
    leaves sharded across more than one device become host numpy, so stored
    blobs are mesh-shape-agnostic (a 2x4-mesh snapshot resumes on one device
    and vice versa). Single-device leaves pass through untouched — the lazy
    no-sync capture of §9 is preserved exactly where it existed."""
    import jax

    def one(a):
        if isinstance(a, jax.Array) and len(a.sharding.device_set) > 1:
            return np.asarray(a)
        return a

    return jax.tree_util.tree_map(one, tree)


def prefix_hash_chain(tokens: np.ndarray, seg_len: int) -> List[bytes]:
    """Rolling hash over segment token ids: entry c-1 keys the boundary
    after c full segments. digest(c) = H(digest(c-1) || segment_c), so the
    whole chain for a P-token prompt is one O(P) pass and extending a
    cached prefix by one segment is O(seg_len)."""
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    assert toks.ndim == 1, toks.shape
    out: List[bytes] = []
    h = b"rmt-prefix-v1"
    for c in range(toks.shape[0] // seg_len):
        seg = toks[c * seg_len:(c + 1) * seg_len]
        h = hashlib.blake2b(h + seg.tobytes(), digest_size=16).digest()
        out.append(h)
    return out


@dataclass
class SegmentSnapshot:
    """Recurrent state at a segment boundary (pos=0, segment cache empty)."""
    tokens: np.ndarray        # int32 [c * seg_len] — the exact prefix
    state: Any                # {'prelude','pattern'} recurrent leaves, B=1
    logits: Any               # [1, V] fp32 logits at the boundary
    n_segments: int
    nbytes: int


@dataclass
class SessionEntry:
    """Persisted end-of-generation state of one conversation."""
    tokens: np.ndarray        # int32 — full consumed history (prompt+output)
    state: Any                # {'prelude','pattern'} full decode leaves, B=1
    pos: int                  # in-segment position of `state`
    pending: np.ndarray       # int32 — emitted but not yet consumed tokens;
    #                           fed before the next turn's prompt on resume
    nbytes: int = 0


class SessionEvicted(KeyError):
    """The session's state was evicted under the byte budget with no disk
    spill configured — it cannot be resumed exactly."""


# ---------------------------------------------------------------------------
# Shared LRU byte-budget store with optional disk spill
# ---------------------------------------------------------------------------

@dataclass
class _Slot:
    payload: Any              # pytree of arrays; None when spilled/dropped
    meta: Dict[str, Any]      # small host-resident metadata (tokens, pos, ..)
    nbytes: int
    spilled: bool = False
    treedef: Any = None       # kept while spilled, to rebuild the pytree


@dataclass
class StoreStats:
    hits: int = 0
    misses: int = 0
    insertions: int = 0
    evictions: int = 0
    spills: int = 0
    restores: int = 0
    collisions: int = 0
    bytes_in_ram: int = 0

    def as_dict(self) -> Dict[str, int]:
        return dict(vars(self))


class _ByteLRU:
    """OrderedDict-backed LRU keyed by opaque strings/bytes; payloads are
    pytrees of arrays. Over-budget entries are evicted oldest-first: spilled
    to disk via CheckpointManager named blobs when available, else dropped
    (optionally leaving a tombstone so the owner can distinguish "never
    seen" from "lost")."""

    def __init__(self, max_bytes: int, *, spill=None, spill_dir=None,
                 namespace: str = "blob", tombstone_on_drop: bool = False):
        if spill is None and spill_dir is not None:
            from repro.checkpoint.manager import CheckpointManager
            spill = CheckpointManager(spill_dir, keep=0, async_save=False)
        self.max_bytes = int(max_bytes)
        self.spill = spill
        self.namespace = namespace
        self.tombstone_on_drop = tombstone_on_drop
        self.entries: "OrderedDict[Any, _Slot]" = OrderedDict()
        self.tombstones: set = set()
        self.stats = StoreStats()

    # -- helpers ----------------------------------------------------------
    def _spill_name(self, key) -> str:
        k = key.hex() if isinstance(key, bytes) else str(key)
        return f"{self.namespace}/{k}"

    def _evict_to_budget(self) -> None:
        import jax
        while self.stats.bytes_in_ram > self.max_bytes:
            victim = next((k for k, s in self.entries.items()
                           if s.payload is not None), None)
            if victim is None:
                return
            slot = self.entries[victim]
            self.stats.bytes_in_ram -= slot.nbytes
            self.stats.evictions += 1
            if self.spill is not None:
                leaves, treedef = jax.tree_util.tree_flatten(slot.payload)
                self.spill.save_named(
                    self._spill_name(victim),
                    {str(i): np.asarray(a) for i, a in enumerate(leaves)})
                slot.treedef = treedef
                slot.payload, slot.spilled = None, True
                self.stats.spills += 1
            else:
                del self.entries[victim]
                if self.tombstone_on_drop:
                    self.tombstones.add(victim)

    # -- public -----------------------------------------------------------
    def put(self, key, payload: Any, meta: Dict[str, Any]) -> None:
        old = self.entries.pop(key, None)
        if old is not None and old.payload is not None:
            self.stats.bytes_in_ram -= old.nbytes
        self.tombstones.discard(key)
        payload = gather_to_host(payload)   # mesh-shape-agnostic blobs (§10)
        nbytes = tree_nbytes(payload)
        self.entries[key] = _Slot(payload=payload, meta=meta, nbytes=nbytes)
        self.stats.bytes_in_ram += nbytes
        self.stats.insertions += 1
        self._evict_to_budget()

    def get(self, key) -> Optional[_Slot]:
        """Returns the slot with payload resident (restoring from disk if it
        was spilled), or None if unknown. Raises KeyError via the owner for
        tombstoned keys — the owner checks ``is_tombstoned`` first."""
        slot = self.entries.get(key)
        if slot is None:
            return None
        if slot.payload is None and slot.spilled:
            import jax
            arrays = self.spill.restore_named(self._spill_name(key))
            slot.payload = jax.tree_util.tree_unflatten(
                slot.treedef, list(arrays.values()))
            slot.spilled, slot.treedef = False, None
            self.stats.bytes_in_ram += slot.nbytes
            self.stats.restores += 1
            # a burst of restores must not grow resident bytes past the
            # budget — re-evict after unspilling. The restored entry is
            # made MRU first, so it is spilled straight back only if it
            # alone exceeds the budget; in that case the caller gets a
            # transient view holding the payload (its RAM is freed when
            # the caller drops it) while the store keeps only the stub.
            self.entries.move_to_end(key)
            payload = slot.payload
            self._evict_to_budget()
            if slot.payload is None:
                return _Slot(payload=payload, meta=slot.meta,
                             nbytes=slot.nbytes)
        self.entries.move_to_end(key)
        return slot

    def is_tombstoned(self, key) -> bool:
        return key in self.tombstones

    def __len__(self) -> int:
        return len(self.entries)

    def __contains__(self, key) -> bool:
        return key in self.entries


# ---------------------------------------------------------------------------
# Prefix cache
# ---------------------------------------------------------------------------

class PrefixCache:
    """Content-addressed cache of segment-boundary snapshots.

    Keys are the rolling segment hash (prefix_hash_chain); a match walks a
    prompt's boundaries longest-first and verifies the candidate's full
    token ids before returning it (hash-collision safety — a colliding key
    must never transplant someone else's memory state).
    """

    def __init__(self, seg_len: int, *, max_bytes: int = 256 << 20,
                 spill_dir=None, spill=None):
        assert seg_len >= 1
        self.seg_len = seg_len
        self._lru = _ByteLRU(max_bytes, spill=spill, spill_dir=spill_dir,
                             namespace="prefix", tombstone_on_drop=False)

    @property
    def stats(self) -> StoreStats:
        return self._lru.stats

    def __len__(self) -> int:
        return len(self._lru)

    def match(self, tokens: np.ndarray, *, chain: Optional[List[bytes]] = None
              ) -> Tuple[int, Optional[SegmentSnapshot]]:
        """Longest cached prefix of ``tokens`` at segment granularity.
        Returns (n_cached_segments, snapshot) — (0, None) on a miss.
        chain: this prompt's precomputed prefix_hash_chain, so one O(P)
        pass serves both the match and every subsequent insert."""
        tokens = np.asarray(tokens, np.int32)
        if chain is None:
            chain = prefix_hash_chain(tokens, self.seg_len)
        for c in range(len(chain), 0, -1):
            key = chain[c - 1]
            slot = self._lru.entries.get(key)
            if slot is None:
                continue
            if not np.array_equal(slot.meta["tokens"], tokens[:c * self.seg_len]):
                # hash collision: the stored prefix is NOT this prompt's
                # prefix — serving it would transplant another context's
                # memory. Fall through to shorter boundaries.
                self._lru.stats.collisions += 1
                continue
            slot = self._lru.get(key)            # unspill + touch LRU
            self._lru.stats.hits += 1
            return c, SegmentSnapshot(
                tokens=slot.meta["tokens"],
                state=slot.payload["state"], logits=slot.payload["logits"],
                n_segments=c, nbytes=slot.nbytes)
        self._lru.stats.misses += 1
        return 0, None

    def insert(self, tokens: np.ndarray, state: Any, logits: Any,
               *, key: Optional[bytes] = None) -> bool:
        """Cache the boundary snapshot for the full-segment prefix
        ``tokens`` (length must be a segment multiple). Returns False if an
        identical prefix is already cached (its LRU recency is refreshed).
        key: this prefix's rolling-hash digest when the caller already
        computed the chain (one pass per admission, not one per boundary —
        the hash-chain cost stays O(P) even for prompts with hundreds of
        segments)."""
        tokens = np.ascontiguousarray(np.asarray(tokens, np.int32))
        assert tokens.ndim == 1 and tokens.shape[0] % self.seg_len == 0, \
            tokens.shape
        if key is None:
            key = prefix_hash_chain(tokens, self.seg_len)[-1]
        slot = self._lru.entries.get(key)
        if slot is not None and np.array_equal(slot.meta["tokens"], tokens):
            self._lru.entries.move_to_end(key)
            return False
        self._lru.put(key, {"state": state, "logits": logits},
                      {"tokens": tokens})
        return True


# ---------------------------------------------------------------------------
# Session store
# ---------------------------------------------------------------------------

class SessionStore:
    """End-of-generation decode states keyed by session_id, for O(new turn)
    multi-turn resume. ``get`` returns None for a session never seen (first
    turn) and raises SessionEvicted for one dropped under the byte budget
    without disk spill — the two must not be confused, or a lost session
    would silently restart with no memory of the conversation."""

    def __init__(self, *, max_bytes: int = 512 << 20, spill_dir=None,
                 spill=None):
        self._lru = _ByteLRU(max_bytes, spill=spill, spill_dir=spill_dir,
                             namespace="session", tombstone_on_drop=True)

    @property
    def stats(self) -> StoreStats:
        return self._lru.stats

    def __len__(self) -> int:
        return len(self._lru)

    def __contains__(self, session_id: str) -> bool:
        return session_id in self._lru

    def get(self, session_id: str) -> Optional[SessionEntry]:
        if self._lru.is_tombstoned(session_id):
            raise SessionEvicted(
                f"session {session_id!r} was evicted under the byte budget "
                "(no spill dir configured); it cannot be resumed exactly")
        slot = self._lru.get(session_id)
        if slot is None:
            self._lru.stats.misses += 1
            return None
        self._lru.stats.hits += 1
        return SessionEntry(tokens=slot.meta["tokens"], state=slot.payload,
                            pos=slot.meta["pos"],
                            pending=slot.meta["pending"], nbytes=slot.nbytes)

    def put(self, session_id: str, *, state: Any, pos: int,
            pending: np.ndarray, tokens: np.ndarray) -> None:
        self._lru.put(session_id, state,
                      {"tokens": np.asarray(tokens, np.int32),
                       "pos": int(pos),
                       "pending": np.asarray(pending, np.int32)})

    def delete(self, session_id: str) -> None:
        slot = self._lru.entries.pop(session_id, None)
        if slot is not None and slot.payload is not None:
            self._lru.stats.bytes_in_ram -= slot.nbytes
        self._lru.tombstones.discard(session_id)
