from repro.serve.engine import ServeEngine, GenerationResult
from repro.serve.scheduler import (ContinuousScheduler, Request, StreamEvent)
