from repro.serve.engine import (AdmissionPool, ServeEngine, GenerationResult,
                                PrefillPipeline)
from repro.serve.scheduler import (ContinuousScheduler, Request, RequestError,
                                   StreamEvent)
from repro.serve.state_store import (PrefixCache, SegmentSnapshot,
                                     SessionEntry, SessionEvicted,
                                     SessionStore, prefix_hash_chain)
from repro.serve.telemetry import (MetricsRegistry, Telemetry, TraceRecorder,
                                   default_registry, validate_chrome_trace)
