"""Serve-stack telemetry: unified metrics registry + chunk-granular trace
timeline (DESIGN.md §13).

Two halves, bundled by :class:`Telemetry` and threaded through the whole
serve stack (engine, scheduler, prefill pipeline, state stores, sharding
fallbacks, launch driver, benchmarks):

* :class:`MetricsRegistry` — labelled counters / gauges / histograms plus
  *probes* (callables sampled at snapshot time — the engine registers its
  jit-cache sizes and store stats this way, so a snapshot is always
  current without per-call bookkeeping). A process-wide default registry
  (:func:`default_registry`) collects cross-cutting series: XLA backend
  compiles (via ``jax.monitoring``) and ``parallel/sharding.py``'s
  replication-fallback counter.

* :class:`TraceRecorder` — host-clock spans with per-request lanes,
  exportable as Chrome-trace / Perfetto JSON (``chrome://tracing``,
  https://ui.perfetto.dev). The scheduler emits spans for every decode
  chunk, admission window, pooled admission round, host-visible segment
  flush, transplant, session restore, prefix-cache probe, and idle-drain
  round. The recorder is also the single source of truth for the serving
  metrics previously re-derived ad hoc in ``benchmarks/bench_serve.py``:
  :meth:`TraceRecorder.itl_values` / :meth:`TraceRecorder.itl_percentiles`
  (inter-token latencies off the per-chunk emit stamps) and
  :meth:`TraceRecorder.admission_stall_s` (max decode gap overlapping an
  admission window).

Hard constraint (carried from PR 2): telemetry is HOST-SIDE ONLY and
piggybacks on the existing once-per-chunk host transfer. Nothing here
calls ``block_until_ready``, converts a ``jax.Array``, or adds per-token
work inside a jitted graph — span/metric arguments are host scalars the
scheduler already owns (slot mirrors, cursors, queue lengths), and the
one-host-transfer-per-chunk invariant is regression-tested with telemetry
enabled (tests/test_telemetry.py). ``jax.named_scope`` annotations inside
the traced bodies and ``jax.profiler.TraceAnnotation`` around dispatches
cost nothing at runtime unless an XLA profile is being captured — they
exist so profiler timelines of the jitted launches line up with the
scheduler's host spans.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["MetricsRegistry", "TraceRecorder", "Telemetry",
           "default_registry", "validate_chrome_trace"]


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def _series_key(name: str, labels: Dict[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Labelled counters / gauges / histograms with a JSON-able snapshot.

    Series are keyed Prometheus-style — ``name{label=value,...}`` — so the
    snapshot is a flat, diffable dict. Histograms keep their raw values
    (these registries are per-run / reset-per-drive, not long-lived
    daemons) and summarize to count/sum/mean/p50/p99/max at snapshot time.

    ``register_probe(name, fn)`` attaches a callable sampled at snapshot
    time under ``probes[name]`` — the engine publishes jit-cache sizes and
    state-store stats this way, so they are always current and cost
    nothing per chunk. ``register_reset_hook(fn)`` runs ``fn`` on
    ``reset()`` — ``parallel/sharding.py`` hooks its warning-dedup set in,
    unifying the old ``reset_fallback_warnings`` test hook with the
    registry's reset.
    """

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, List[float]] = {}
        self._probes: Dict[str, Callable[[], Any]] = {}
        self._reset_hooks: List[Callable[[], None]] = []

    # -- write paths (cheap: one dict op each) ----------------------------
    def inc(self, name: str, n: float = 1, **labels) -> None:
        k = _series_key(name, labels)
        self.counters[k] = self.counters.get(k, 0) + n

    def set_gauge(self, name: str, value: float, **labels) -> None:
        self.gauges[_series_key(name, labels)] = value

    def observe(self, name: str, value: float, **labels) -> None:
        self.histograms.setdefault(_series_key(name, labels), []).append(
            float(value))

    # -- probes / reset ---------------------------------------------------
    def register_probe(self, name: str, fn: Callable[[], Any]) -> None:
        self._probes[name] = fn

    def register_reset_hook(self, fn: Callable[[], None]) -> None:
        if fn not in self._reset_hooks:
            self._reset_hooks.append(fn)

    def remove_series(self, name: str) -> None:
        """Drop every series of ``name`` (any labels) from all kinds."""
        for store in (self.counters, self.gauges, self.histograms):
            for k in [k for k in store
                      if k == name or k.startswith(name + "{")]:
                del store[k]

    def reset(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.histograms.clear()
        for fn in self._reset_hooks:
            fn()

    # -- read path --------------------------------------------------------
    @staticmethod
    def _summarize(values: List[float]) -> Dict[str, float]:
        arr = np.asarray(values, np.float64)
        return {"count": int(arr.size), "sum": float(arr.sum()),
                "mean": float(arr.mean()), "p50": float(np.percentile(arr, 50)),
                "p99": float(np.percentile(arr, 99)), "max": float(arr.max())}

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able view: counters/gauges verbatim, histograms summarized,
        probes sampled now. Probe failures surface as an ``error`` string
        instead of killing the snapshot (a metrics read must never take
        the serve loop down)."""
        probes = {}
        for name, fn in self._probes.items():
            try:
                probes[name] = fn()
            except Exception as e:            # pragma: no cover - defensive
                probes[name] = {"error": f"{type(e).__name__}: {e}"}
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: self._summarize(v)
                           for k, v in self.histograms.items()},
            "probes": probes,
        }


# -- process-wide default registry + XLA compile listener -------------------

_DEFAULT: Optional[MetricsRegistry] = None
_compile_listener_installed = False


def _install_compile_listener() -> None:
    """Count actual XLA backend compiles (and their total seconds) into the
    default registry via ``jax.monitoring`` — ground truth under the
    jit-cache-size probes: pow2 bucketing claims O(log) compiled programs,
    and this counter is what finally verifies it end to end (a retrace
    that silently recompiles an existing cache entry still shows up
    here)."""
    global _compile_listener_installed
    if _compile_listener_installed:
        return
    import jax.monitoring

    def on_duration(event: str, duration: float, **kw) -> None:
        if event == "/jax/core/compile/backend_compile_duration":
            reg = default_registry()
            reg.inc("xla_backend_compiles_total")
            reg.inc("xla_backend_compile_secs_total", duration)

    jax.monitoring.register_event_duration_secs_listener(on_duration)
    _compile_listener_installed = True


def default_registry() -> MetricsRegistry:
    """The process-wide registry for cross-cutting series: XLA backend
    compile counts/seconds and sharding replication fallbacks. Engines
    default their :class:`Telemetry` to this registry, so one snapshot
    carries scheduler metrics and the global series together; tests
    wanting isolation pass ``Telemetry(registry=MetricsRegistry())``."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = MetricsRegistry()
    _install_compile_listener()
    return _DEFAULT


# ---------------------------------------------------------------------------
# Trace recorder (Chrome trace / Perfetto)
# ---------------------------------------------------------------------------

# span categories the scheduler/engine emit — the schema check validates
# category membership so a typo'd span name cannot silently vanish from
# timeline queries
SPAN_CATEGORIES = ("decode", "admission", "prefill", "flush", "transplant",
                   "session", "cache", "idle", "generate", "emit")


@dataclass
class _Span:
    name: str
    cat: str
    t0: float                   # perf_counter seconds
    t1: float
    lane: Optional[str]         # None -> the scheduler lane
    args: Dict[str, Any] = field(default_factory=dict)


class _SpanCtx:
    """Hot-path span context: stamps the host clock and enters a
    ``jax.profiler.TraceAnnotation`` (a ~ns-cost TraceMe — when a profile
    is being captured, the XLA timeline gets a host span lining up with
    the recorder's: same name, same interval)."""

    __slots__ = ("rec", "name", "cat", "lane", "args", "t0", "_ann")

    def __init__(self, rec: "TraceRecorder", name: str, cat: str,
                 lane: Optional[str], args: Dict[str, Any]):
        self.rec, self.name, self.cat = rec, name, cat
        self.lane, self.args = lane, args

    def __enter__(self):
        self._ann = _profiler().TraceAnnotation(self.name)
        self.t0 = time.perf_counter()
        self._ann.__enter__()
        return self

    def __exit__(self, *exc):
        self._ann.__exit__(*exc)
        self.rec.spans.append(_Span(self.name, self.cat, self.t0,
                                    time.perf_counter(), self.lane,
                                    self.args))
        return False


_PROFILER = None


def _profiler():
    global _PROFILER
    if _PROFILER is None:
        import jax.profiler
        _PROFILER = jax.profiler
    return _PROFILER


class TraceRecorder:
    """Host-clock span/instant recorder with per-request lanes.

    Lanes map to Chrome-trace threads: lane ``None`` is the scheduler's
    own timeline (tid 0); every distinct lane string (request ids, mostly)
    gets its own tid with a ``thread_name`` metadata record, so Perfetto
    shows one swimlane per request under the scheduler track.

    ``emit(req_id, t, n)`` records the per-chunk token emissions the
    derived serving metrics are computed from — one entry per (request,
    chunk boundary), NOT per token; expansion to per-token stamps happens
    only inside :meth:`itl_values` (every token of a chunk shares the
    chunk-boundary host stamp, by design — chunk-granular latency).
    """

    def __init__(self, t0: Optional[float] = None):
        self.t0 = time.perf_counter() if t0 is None else t0
        self.spans: List[_Span] = []
        self.instants: List[_Span] = []
        # req_id -> [(t_emit, n_tokens), ...] per chunk boundary
        self.emits: Dict[Any, List[Tuple[float, int]]] = {}

    # -- recording --------------------------------------------------------
    def span(self, name: str, cat: str, lane: Optional[str] = None, **args):
        # hand-rolled context manager: this sits on the per-chunk hot path,
        # and a contextlib generator costs several µs per entry — enough to
        # show up in the paired overhead ratio at smoke model scale
        return _SpanCtx(self, name, cat, lane, args)

    def add_span(self, name: str, cat: str, t0: float, t1: float,
                 lane: Optional[str] = None, **args) -> None:
        """Retroactive span from host stamps already taken (e.g. an
        admission window stamped at start and transplant time)."""
        self.spans.append(_Span(name, cat, t0, t1, lane, args))

    def instant(self, name: str, cat: str, t: Optional[float] = None,
                lane: Optional[str] = None, **args) -> None:
        t = time.perf_counter() if t is None else t
        self.instants.append(_Span(name, cat, t, t, lane, args))

    def emit(self, req_id, t: float, n_tokens: int) -> None:
        self.emits.setdefault(req_id, []).append((t, n_tokens))
        self.instants.append(_Span("tokens", "emit", t, t, str(req_id),
                                   {"n": n_tokens}))

    # -- derived serving metrics (one source of truth for the bench) ------
    def itl_values(self) -> List[float]:
        """Pooled per-request inter-token latencies. Every token of a chunk
        carries the chunk-boundary stamp, so a chunk of n tokens
        contributes n-1 zero gaps plus one inter-chunk gap — identical to
        the per-token ``StreamEvent.t_emit`` scan the bench used to do."""
        itls: List[float] = []
        for chunks in self.emits.values():
            prev_t = None
            for (t, n) in chunks:
                if prev_t is not None:
                    itls.append(t - prev_t)
                itls.extend([0.0] * (n - 1))
                prev_t = t
        return itls

    def itl_percentiles(self) -> Tuple[float, float]:
        itls = self.itl_values()
        if not itls:
            return 0.0, 0.0
        return (float(np.percentile(itls, 50)),
                float(np.percentile(itls, 99)))

    def admission_windows(self) -> List[Tuple[float, float]]:
        return [(s.t0, s.t1) for s in self.spans if s.name == "admission"]

    def admission_stall_s(self) -> float:
        """Max decode gap (between consecutive chunk-boundary emit stamps,
        any request) whose interval overlaps an admission window — the
        head-of-line stall an admission inflicts on already-decoding
        slots. 0.0 when no admission overlapped active decode."""
        times = sorted({t for chunks in self.emits.values()
                        for (t, _n) in chunks})
        gaps = list(zip(times, times[1:]))
        stall = 0.0
        for (w0, w1) in self.admission_windows():
            for (a, b) in gaps:
                if a <= w1 and b >= w0:
                    stall = max(stall, b - a)
        return stall

    # -- export -----------------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        """The recorder's timeline as a Chrome-trace JSON object (Perfetto
        and chrome://tracing both load it). Times are microseconds
        relative to the recorder's ``t0``; spans are complete ("X")
        events, instants "i", lanes become named threads of pid 1."""
        lanes: Dict[Optional[str], int] = {None: 0}
        events: List[Dict[str, Any]] = [
            {"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
             "args": {"name": "repro.serve"}},
            {"ph": "M", "pid": 1, "tid": 0, "name": "thread_name",
             "args": {"name": "scheduler"}},
        ]

        def tid(lane: Optional[str]) -> int:
            if lane not in lanes:
                lanes[lane] = len(lanes)
                events.append({"ph": "M", "pid": 1, "tid": lanes[lane],
                               "name": "thread_name",
                               "args": {"name": f"req:{lane}"}})
            return lanes[lane]

        for s in self.spans:
            events.append({"ph": "X", "pid": 1, "tid": tid(s.lane),
                           "name": s.name, "cat": s.cat,
                           "ts": (s.t0 - self.t0) * 1e6,
                           "dur": max((s.t1 - s.t0) * 1e6, 0.0),
                           "args": s.args})
        for s in self.instants:
            events.append({"ph": "i", "pid": 1, "tid": tid(s.lane),
                           "name": s.name, "cat": s.cat, "s": "t",
                           "ts": (s.t0 - self.t0) * 1e6, "args": s.args})
        events.sort(key=lambda e: e.get("ts", -1.0))
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
            f.write("\n")


def validate_chrome_trace(trace: Any) -> List[str]:
    """Schema check for an emitted trace (CI gate): ``trace`` is a path or
    an already-loaded object. Returns a list of problems — empty means
    valid. Checks the Chrome-trace envelope, per-event required fields,
    category membership for X/i events, and that every referenced tid has
    a ``thread_name`` metadata record."""
    if isinstance(trace, str):
        with open(trace) as f:
            trace = json.load(f)
    errs: List[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["top level must be an object with a 'traceEvents' list"]
    events = trace["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["'traceEvents' must be a non-empty list"]
    named_tids = set()
    used_tids = set()
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = e.get("ph")
        if ph not in ("X", "i", "M", "C"):
            errs.append(f"event {i}: unknown ph {ph!r}")
            continue
        for k in ("name", "pid", "tid"):
            if k not in e:
                errs.append(f"event {i} ({e.get('name')!r}): missing {k!r}")
        if ph == "M":
            if e.get("name") == "thread_name":
                named_tids.add((e.get("pid"), e.get("tid")))
            continue
        used_tids.add((e.get("pid"), e.get("tid")))
        if "ts" not in e:
            errs.append(f"event {i} ({e.get('name')!r}): missing 'ts'")
        elif not isinstance(e["ts"], (int, float)) or e["ts"] < 0:
            errs.append(f"event {i} ({e.get('name')!r}): bad ts {e['ts']!r}")
        if e.get("cat") not in SPAN_CATEGORIES:
            errs.append(f"event {i} ({e.get('name')!r}): unknown cat "
                        f"{e.get('cat')!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"event {i} ({e.get('name')!r}): bad dur {dur!r}")
    for t in used_tids - named_tids:
        errs.append(f"tid {t} used but never named via thread_name metadata")
    return errs


# ---------------------------------------------------------------------------
# Telemetry bundle
# ---------------------------------------------------------------------------

# memory_stats() probe cache: None = unprobed, False = backend has no
# stats (CPU), otherwise the device to sample
_MEM_DEVICE: Any = None


class _NullCtx:
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL = _NullCtx()


class Telemetry:
    """What the serve stack actually holds: a registry (metrics) and an
    optional trace recorder, with every write path guarded so a disabled
    instance is a handful of attribute checks per CHUNK (never per token).

    * ``Telemetry()`` — metrics into the process default registry, no
      trace. The engine's default.
    * ``Telemetry(trace=True)`` — adds the span recorder (``--trace-out``,
      bench drives).
    * ``Telemetry.disabled()`` — everything off (the overhead baseline in
      EXPERIMENTS.md §Observability).
    """

    def __init__(self, *, metrics: bool = True, trace: bool = False,
                 registry: Optional[MetricsRegistry] = None):
        self.registry = (registry if registry is not None
                         else (default_registry() if metrics else None))
        self.trace: Optional[TraceRecorder] = (TraceRecorder() if trace
                                               else None)

    @classmethod
    def disabled(cls) -> "Telemetry":
        return cls(metrics=False, trace=False)

    @property
    def on(self) -> bool:
        return self.registry is not None or self.trace is not None

    # -- metrics (no-ops without a registry) ------------------------------
    def inc(self, name: str, n: float = 1, **labels) -> None:
        if self.registry is not None:
            self.registry.inc(name, n, **labels)

    def set_gauge(self, name: str, value: float, **labels) -> None:
        if self.registry is not None:
            self.registry.set_gauge(name, value, **labels)

    def observe(self, name: str, value: float, **labels) -> None:
        if self.registry is not None:
            self.registry.observe(name, value, **labels)

    # -- spans (no-ops without a recorder) --------------------------------
    def span(self, name: str, cat: str, lane: Optional[str] = None, **args):
        if self.trace is None:
            return _NULL
        return self.trace.span(name, cat, lane=lane, **args)

    def add_span(self, name: str, cat: str, t0: float, t1: float,
                 lane: Optional[str] = None, **args) -> None:
        if self.trace is not None:
            self.trace.add_span(name, cat, t0, t1, lane=lane, **args)

    def instant(self, name: str, cat: str, t: Optional[float] = None,
                lane: Optional[str] = None, **args) -> None:
        if self.trace is not None:
            self.trace.instant(name, cat, t=t, lane=lane, **args)

    def emit(self, req_id, t: float, n_tokens: int) -> None:
        if self.trace is not None:
            self.trace.emit(req_id, t, n_tokens)

    def sample_device_memory(self) -> None:
        """Chunk-boundary device-memory gauge — ``Device.memory_stats()``
        is a host-side query (no sync); absent on CPU, so this is a no-op
        there (the first empty probe remembers the backend as statless,
        keeping the per-chunk cost to one comparison)."""
        global _MEM_DEVICE
        if self.registry is None or _MEM_DEVICE is False:
            return
        if _MEM_DEVICE is None:
            import jax
            dev = jax.local_devices()[0]
            if not dev.memory_stats():
                _MEM_DEVICE = False
                return
            _MEM_DEVICE = dev
        stats = _MEM_DEVICE.memory_stats()
        if stats:
            for k in ("bytes_in_use", "peak_bytes_in_use"):
                if k in stats:
                    self.registry.set_gauge(f"device_{k}", int(stats[k]))

    def snapshot(self) -> Optional[Dict[str, Any]]:
        return self.registry.snapshot() if self.registry is not None else None


# ---------------------------------------------------------------------------
# CLI: python -m repro.serve.telemetry trace.json  (CI schema gate)
# ---------------------------------------------------------------------------

def _main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="Validate a Chrome-trace JSON emitted by "
                    "launch/serve.py --trace-out (CI schema gate)")
    ap.add_argument("trace", help="path to the trace JSON")
    ap.add_argument("--min-spans", type=int, default=1,
                    help="fail unless at least this many X spans exist")
    ap.add_argument("--require-cats", default="",
                    help="comma list of categories that must appear")
    args = ap.parse_args(argv)
    errs = validate_chrome_trace(args.trace)
    with open(args.trace) as f:
        obj = json.load(f)
    events = obj.get("traceEvents", []) if isinstance(obj, dict) else []
    spans = [e for e in events if isinstance(e, dict) and e.get("ph") == "X"]
    if len(spans) < args.min_spans:
        errs.append(f"only {len(spans)} spans, need >= {args.min_spans}")
    # instants count toward category coverage (in-graph segment flushes are
    # host-derived instants, not spans)
    cats = {e.get("cat") for e in events
            if isinstance(e, dict) and e.get("ph") in ("X", "i")}
    for c in filter(None, args.require_cats.split(",")):
        if c not in cats:
            errs.append(f"required category {c!r} absent (have {sorted(cats)})")
    if errs:
        for e in errs:
            print(f"TRACE-INVALID: {e}")
        return 1
    print(f"trace OK: {len(spans)} spans, {len(events)} events, "
          f"categories={sorted(c for c in cats if c)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
