"""ARMT associative memory — fused Pallas TPU kernels.

The paper-specific hot spot (eqs. 3-6). Two kernels:

  armt_read:   out = (phi(x Wq) A) / (phi(x Wq) . z + eps), tiled over tokens
               and the value dim; phi (DPFP-nu) is computed in VMEM and never
               materialized in HBM.
  armt_update: delta-rule A' = A + sum_i beta_i (v_i - vbar_i) phi(k_i)^T,
               z' = z + sum_i gamma_i phi(k_i), tiled over the value dim
               (memory tokens M is small — one block).

Layout: x [N, T, D], A [N, P, Dv], z [N, P] with N = group*batch (the diagonal
executor's grouped launch), P = 2*nu*d_mem.

Projection weights may be shared across N (``wq: [D, dm]``) or stacked per
group (``wq: [G, D, dm]`` with N = G*batch) — the grouped-block fast path
stacks per-layer weights on the group dim and the BlockSpec index map picks
row ``n // batch``; the kernel bodies are identical in both cases.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-6


def _wspec(w, N: int, last_block=None, last_axis=None):
    """BlockSpec for a projection weight: shared ``[D, E]`` or per-group
    ``[G, D, E]`` (row ``n // batch``, batch = N // G). ``last_block`` tiles
    the final weight dim over grid axis ``last_axis``."""
    D = w.shape[-2]
    E = last_block if last_block is not None else w.shape[-1]
    if w.ndim == 2:
        def idx(n, *rest):
            return (0, rest[last_axis] if last_axis is not None else 0)
        return pl.BlockSpec((D, E), idx)
    batch, r = divmod(N, w.shape[0])
    assert r == 0, f"N={N} not divisible by weight groups G={w.shape[0]}"

    def gidx(n, *rest):
        return (n // batch, 0, rest[last_axis] if last_axis is not None else 0)
    return pl.BlockSpec((None, D, E), gidx)


def _dpfp(x, nu: int):
    r = jnp.concatenate([jnp.maximum(x, 0), jnp.maximum(-x, 0)], axis=-1)
    return jnp.concatenate(
        [r * jnp.roll(r, j, axis=-1) for j in range(1, nu + 1)], axis=-1)


def _read_kernel(x_ref, wq_ref, a_ref, z_ref, o_ref, *, nu: int):
    # x: [bt, D], wq: [D, dm], a: [P, bv], z: [P], o: [bt, bv]
    x = x_ref[...].astype(jnp.float32)
    q = x @ wq_ref[...].astype(jnp.float32)
    pq = _dpfp(q, nu)                                       # [bt, P]
    num = pq @ a_ref[...].astype(jnp.float32)               # [bt, bv]
    den = pq @ z_ref[...].astype(jnp.float32)[:, None]      # [bt, 1]
    o_ref[...] = (num / (den + EPS)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("nu", "block_t", "block_v", "interpret"))
def armt_read(x, wq, A, z, *, nu: int = 3, block_t: int = 256,
              block_v: int = 512, interpret: bool = False):
    """x: [N,T,D], wq: [D,dm] or [G,D,dm], A: [N,P,Dv], z: [N,P] -> [N,T,Dv]."""
    N, T, D = x.shape
    _, P, Dv = A.shape
    block_t = min(block_t, T)
    block_v = min(block_v, Dv)
    grid = (N, pl.cdiv(T, block_t), pl.cdiv(Dv, block_v))
    return pl.pallas_call(
        functools.partial(_read_kernel, nu=nu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_t, D), lambda n, it, iv: (n, it, 0)),
            _wspec(wq, N),
            pl.BlockSpec((None, P, block_v), lambda n, it, iv: (n, 0, iv)),
            pl.BlockSpec((None, P), lambda n, it, iv: (n, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_t, block_v),
                               lambda n, it, iv: (n, it, iv)),
        out_shape=jax.ShapeDtypeStruct((N, T, Dv), x.dtype),
        interpret=interpret,
    )(x, wq, A, z)


def _update_kernel(m_ref, wk_ref, wv_ref, wb_ref, a_ref, z_ref,
                   a_out_ref, z_out_ref, *, nu: int):
    # m: [M, D]; wk: [D, dm]; wv: [D, bv]; wb: [D, 1];
    # a: [P, bv]; z: [P]  ->  a_out: [P, bv]; z_out: [P]
    m = m_ref[...].astype(jnp.float32)
    k = m @ wk_ref[...].astype(jnp.float32)
    pk = _dpfp(k, nu)                                        # [M, P]
    v = m @ wv_ref[...].astype(jnp.float32)                  # [M, bv]
    beta = jax.nn.sigmoid(m @ wb_ref[...].astype(jnp.float32))  # [M, 1]
    a = a_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    zk = pk @ z[:, None]                                     # [M, 1]
    vbar = (pk @ a) / (zk + EPS)
    a_out_ref[...] = (a + pk.T @ (beta * (v - vbar))).astype(a_out_ref.dtype)

    @pl.when(pl.program_id(1) == 0)
    def _z():
        gamma = 1.0 - zk[:, 0] / (jnp.sum(pk * pk, axis=-1) + EPS)   # [M]
        z_out_ref[...] = (z + (gamma[None, :] @ pk)[0]).astype(z_out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("nu", "block_v", "interpret"))
def armt_update(m, wk, wv, wb, A, z, *, nu: int = 3, block_v: int = 512,
                interpret: bool = False):
    """m: [N,M,D]; wk/wv/wb: [D,*] or [G,D,*]; A: [N,P,Dv]; z: [N,P] -> (A', z')."""
    N, M, D = m.shape
    _, P, Dv = A.shape
    block_v = min(block_v, Dv)
    grid = (N, pl.cdiv(Dv, block_v))
    return pl.pallas_call(
        functools.partial(_update_kernel, nu=nu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, M, D), lambda n, iv: (n, 0, 0)),
            _wspec(wk, N),
            _wspec(wv, N, last_block=block_v, last_axis=0),
            _wspec(wb, N),
            pl.BlockSpec((None, P, block_v), lambda n, iv: (n, 0, iv)),
            pl.BlockSpec((None, P), lambda n, iv: (n, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, P, block_v), lambda n, iv: (n, 0, iv)),
            pl.BlockSpec((None, P), lambda n, iv: (n, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(A.shape, A.dtype),
            jax.ShapeDtypeStruct(z.shape, z.dtype),
        ],
        interpret=interpret,
    )(m, wk, wv, wb, A, z)
