"""Grouped (batched) matmul — Pallas TPU kernel.

TPU analogue of the paper's CUTLASS GroupedGEMM (§3.3): per-layer weights are
stacked on a leading group dim, so the grouped GEMM is a batched GEMM the MXU
executes at peak. Explicit VMEM tiling: [bm, bk] x [bk, bn] tiles with fp32
accumulation over the K grid dimension (output block revisited, initialized
at k==0 — the canonical Pallas accumulation pattern).

Fused epilogue: an optional per-group bias [G, N] and an optional activation
("silu" | "gelu") are applied to the fp32 accumulator in VMEM before the
output store — the QKV bias add and the FFN up-proj + activation never round
trip through HBM (the grouped-block fast path relies on this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ACTIVATIONS = (None, "silu", "gelu")


def _epilogue(acc, activation: str | None):
    if activation == "silu":
        return acc * jax.nn.sigmoid(acc)
    if activation == "gelu":
        return jax.nn.gelu(acc, approximate=True)
    return acc


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int,
                activation: str | None):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        o_ref[...] = _epilogue(acc_ref[...], activation).astype(o_ref.dtype)


def _gmm_bias_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int,
                     activation: str | None):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        acc = acc_ref[...] + b_ref[...].astype(jnp.float32)[None, :]
        o_ref[...] = _epilogue(acc, activation).astype(o_ref.dtype)


def _gmm_armt_kernel(x_ref, w_ref, res_ref, b_ref, wk_ref, wv_ref, wb_ref,
                     a_ref, z_ref, y_ref, a_out_ref, z_out_ref, acc_ref, *,
                     n_m: int, n_k: int, mem_off: int, M: int, nu: int):
    from repro.kernels.armt_memory import EPS, _dpfp
    im, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        out = (acc_ref[...] + b_ref[...].astype(jnp.float32)[None, :]
               + res_ref[...].astype(jnp.float32))
        y = out.astype(y_ref.dtype)
        y_ref[...] = y

        # ARMT delta-rule epilogue on the tile holding the memory tokens:
        # identical math to armt_memory._update_kernel, fed from the y tile
        # already resident in VMEM (cast to the activation dtype first, so
        # fused == unfused bit-for-bit — the unfused path reads y from HBM).
        @pl.when(im == n_m - 1)
        def _armt():
            m = y[mem_off:mem_off + M, :].astype(jnp.float32)
            k = m @ wk_ref[...].astype(jnp.float32)
            pk = _dpfp(k, nu)                                    # [M, P]
            v = m @ wv_ref[...].astype(jnp.float32)              # [M, Dv]
            beta = jax.nn.sigmoid(m @ wb_ref[...].astype(jnp.float32))
            a = a_ref[...].astype(jnp.float32)
            z = z_ref[...].astype(jnp.float32)
            zk = pk @ z[:, None]                                 # [M, 1]
            vbar = (pk @ a) / (zk + EPS)
            a_out_ref[...] = (
                a + pk.T @ (beta * (v - vbar))).astype(a_out_ref.dtype)
            gamma = 1.0 - zk[:, 0] / (jnp.sum(pk * pk, axis=-1) + EPS)
            z_out_ref[...] = (
                z + (gamma[None, :] @ pk)[0]).astype(z_out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("M", "nu", "block_m", "block_k", "interpret"))
def grouped_matmul_armt_update(x, w, res, wk, wv, wb, A, z, bias=None, *,
                               M: int, nu: int = 3, block_m: int = 256,
                               block_k: int = 512, interpret: bool = False):
    """Grouped GEMM with residual + fused ARMT memory-update epilogue.

    ``y = res + x @ w (+ bias)`` and, in the same launch, the delta-rule
    update of ``(A, z)`` from the last ``M`` rows of each group's ``y``
    (the memory tokens) — the two separate per-anti-diagonal-cell launches
    of the grouped-block fast path collapsed into one, so the memory
    tokens never round trip through HBM between the down-projection and
    the associative update.

    x: [G, R, K]; w: [G, K, D]; res: [G, R, D]; bias: optional [G, D];
    wk: [G, D, dm] | [D, dm]; wv: [G, D, Dv]; wb: [G, D, 1];
    A: [G, P, Dv]; z: [G, P]  ->  (y [G, R, D], A' [G, P, Dv], z' [G, P]).

    Tiling constraints (checked; ops.py falls back to separate launches
    when unmet): N is full-width (the epilogue needs complete memory-token
    rows) and the last M rows must sit inside the final m-tile.
    """
    from repro.kernels.armt_memory import _wspec
    G, R, K = x.shape
    _, _, D = w.shape
    _, P, Dv = A.shape
    block_m = min(block_m, R)
    block_k = min(block_k, K)
    n_m = pl.cdiv(R, block_m)
    n_k = pl.cdiv(K, block_k)
    rows_last = R - (n_m - 1) * block_m
    assert rows_last >= M, (
        f"mem rows (M={M}) straddle the last m-tile "
        f"(rows_last={rows_last}); use separate launches")
    mem_off = rows_last - M
    if bias is None:
        bias = jnp.zeros((G, D), x.dtype)

    # zero-pad ragged R/K up to block multiples (padded K columns are
    # exact zeros in the accumulator; padded rows sit past the memory
    # tokens in the last m-tile and are sliced off below)
    Rp, Kp = n_m * block_m, n_k * block_k
    if (Rp, Kp) != (R, K):
        x = jnp.pad(x, ((0, 0), (0, Rp - R), (0, Kp - K)))
        w = jnp.pad(w, ((0, 0), (0, Kp - K), (0, 0)))
        res = jnp.pad(res, ((0, 0), (0, Rp - R), (0, 0)))

    grid = (G, n_m, n_k)
    kernel = functools.partial(_gmm_armt_kernel, n_m=n_m, n_k=n_k,
                               mem_off=mem_off, M=M, nu=nu)
    y, A2, z2 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_m, block_k),
                         lambda g, im, ik: (g, im, ik)),
            pl.BlockSpec((None, block_k, D),
                         lambda g, im, ik: (g, ik, 0)),
            pl.BlockSpec((None, block_m, D),
                         lambda g, im, ik: (g, im, 0)),
            pl.BlockSpec((None, D), lambda g, im, ik: (g, 0)),
            _wspec(wk, G),
            _wspec(wv, G),
            _wspec(wb, G),
            pl.BlockSpec((None, P, Dv), lambda g, im, ik: (g, 0, 0)),
            pl.BlockSpec((None, P), lambda g, im, ik: (g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_m, D), lambda g, im, ik: (g, im, 0)),
            pl.BlockSpec((None, P, Dv), lambda g, im, ik: (g, 0, 0)),
            pl.BlockSpec((None, P), lambda g, im, ik: (g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, Rp, D), x.dtype),
            jax.ShapeDtypeStruct(A.shape, A.dtype),
            jax.ShapeDtypeStruct(z.shape, z.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_m, D), jnp.float32)],
        interpret=interpret,
    )(x, w, res, bias, wk, wv, wb, A, z)
    return (y[:, :R, :] if Rp != R else y), A2, z2


@functools.partial(
    jax.jit, static_argnames=("activation", "block_m", "block_n", "block_k",
                              "interpret"))
def grouped_matmul(x, w, bias=None, *, activation: str | None = None,
                   block_m: int = 128, block_n: int = 128,
                   block_k: int = 512, interpret: bool = False):
    """x: [G, M, K], w: [G, K, N] (+ bias [G, N]) -> [G, M, N].

    ``activation`` is applied to the fp32 accumulator (after the bias add)
    inside the kernel epilogue: None | "silu" | "gelu" (tanh approximation,
    matching ``jax.nn.gelu(approximate=True)`` in models/layers.py).
    """
    assert activation in ACTIVATIONS, activation
    G, M, K = x.shape
    _, _, N = w.shape
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)

    # zero-pad ragged dims up to block multiples: padded K columns
    # contribute exactly zero to the fp32 accumulator, padded M rows /
    # N columns are sliced off after the call
    Mp, Np, Kp = (pl.cdiv(d, b) * b for d, b in
                  ((M, block_m), (N, block_n), (K, block_k)))
    if (Mp, Kp) != (M, K):
        x = jnp.pad(x, ((0, 0), (0, Mp - M), (0, Kp - K)))
    if (Kp, Np) != (K, N):
        w = jnp.pad(w, ((0, 0), (0, Kp - K), (0, Np - N)))
    if bias is not None and Np != N:
        bias = jnp.pad(bias, ((0, 0), (0, Np - N)))

    n_k = Kp // block_k
    grid = (G, Mp // block_m, Np // block_n, n_k)
    in_specs = [
        pl.BlockSpec((None, block_m, block_k),
                     lambda g, im, jn, ik: (g, im, ik)),
        pl.BlockSpec((None, block_k, block_n),
                     lambda g, im, jn, ik: (g, ik, jn)),
    ]
    if bias is None:
        kernel = functools.partial(_gmm_kernel, n_k=n_k, activation=activation)
        operands = (x, w)
    else:
        assert bias.shape == (G, Np), (bias.shape, (G, Np))
        in_specs.append(pl.BlockSpec((None, block_n),
                                     lambda g, im, jn, ik: (g, jn)))
        kernel = functools.partial(_gmm_bias_kernel, n_k=n_k,
                                   activation=activation)
        operands = (x, w, bias)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, block_m, block_n),
                               lambda g, im, jn, ik: (g, im, jn)),
        out_shape=jax.ShapeDtypeStruct((G, Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[:, :M, :N] if (Mp, Np) != (M, N) else out
