"""Grouped (batched) matmul — Pallas TPU kernel.

TPU analogue of the paper's CUTLASS GroupedGEMM (§3.3): per-layer weights are
stacked on a leading group dim, so the grouped GEMM is a batched GEMM the MXU
executes at peak. Explicit VMEM tiling: [bm, bk] x [bk, bn] tiles with fp32
accumulation over the K grid dimension (output block revisited, initialized
at k==0 — the canonical Pallas accumulation pattern).

Fused epilogue: an optional per-group bias [G, N] and an optional activation
("silu" | "gelu") are applied to the fp32 accumulator in VMEM before the
output store — the QKV bias add and the FFN up-proj + activation never round
trip through HBM (the grouped-block fast path relies on this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ACTIVATIONS = (None, "silu", "gelu")


def _epilogue(acc, activation: str | None):
    if activation == "silu":
        return acc * jax.nn.sigmoid(acc)
    if activation == "gelu":
        return jax.nn.gelu(acc, approximate=True)
    return acc


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int,
                activation: str | None):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        o_ref[...] = _epilogue(acc_ref[...], activation).astype(o_ref.dtype)


def _gmm_bias_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int,
                     activation: str | None):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        acc = acc_ref[...] + b_ref[...].astype(jnp.float32)[None, :]
        o_ref[...] = _epilogue(acc, activation).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("activation", "block_m", "block_n", "block_k",
                              "interpret"))
def grouped_matmul(x, w, bias=None, *, activation: str | None = None,
                   block_m: int = 128, block_n: int = 128,
                   block_k: int = 512, interpret: bool = False):
    """x: [G, M, K], w: [G, K, N] (+ bias [G, N]) -> [G, M, N].

    ``activation`` is applied to the fp32 accumulator (after the bias add)
    inside the kernel epilogue: None | "silu" | "gelu" (tanh approximation,
    matching ``jax.nn.gelu(approximate=True)`` in models/layers.py).
    """
    assert activation in ACTIVATIONS, activation
    G, M, K = x.shape
    _, _, N = w.shape
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    n_k = pl.cdiv(K, block_k)
    grid = (G, pl.cdiv(M, block_m), pl.cdiv(N, block_n), n_k)
    in_specs = [
        pl.BlockSpec((None, block_m, block_k),
                     lambda g, im, jn, ik: (g, im, ik)),
        pl.BlockSpec((None, block_k, block_n),
                     lambda g, im, jn, ik: (g, ik, jn)),
    ]
    if bias is None:
        kernel = functools.partial(_gmm_kernel, n_k=n_k, activation=activation)
        operands = (x, w)
    else:
        assert bias.shape == (G, N), (bias.shape, (G, N))
        in_specs.append(pl.BlockSpec((None, block_n),
                                     lambda g, im, jn, ik: (g, jn)))
        kernel = functools.partial(_gmm_bias_kernel, n_k=n_k,
                                   activation=activation)
        operands = (x, w, bias)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, block_m, block_n),
                               lambda g, im, jn, ik: (g, im, jn)),
        out_shape=jax.ShapeDtypeStruct((G, M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(*operands)
