"""Offline kernel autotuner (DESIGN.md §14).

Sweeps a backend-appropriate config space per op with *paired* timing
(candidates interleaved round-robin across repeats, so clock drift and
thermal state hit every candidate equally), bit-validates the winner in
interpret mode against the kernels/ref.py oracle, and persists it to the
dispatch layer's disk cache keyed ``(backend, op, shape-bucket, dtype)``.

Strictly offline: the dispatch resolver called inside jit traces is a
pure table lookup — this module is what fills the table. Run it from
``benchmarks/bench_kernels.py`` (or a one-off script) on the target
hardware; every later process cold-starts straight into the tuned
winners via the disk cache.

Registry counters (serve/telemetry.py default registry, or an injected
one):

* ``autotune_sweep_total{op}``        — timed candidate launches
* ``autotune_cache_hit_total{op}``    — ``get_or_tune`` short-circuits
  (the acceptance invariant: a second run with a warm cache performs
  ZERO sweep launches)
* ``autotune_validate_total{op,result}`` — winner validations
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.kernels import dispatch, ops, ref
from repro.kernels.dispatch import KernelConfig

# ---------------------------------------------------------------------------
# Config spaces: (backend, op) -> candidate list. CPU spaces are singleton
# XLA (there is nothing to tune — pallas-interpret is a validation tool);
# the "interpret" pseudo-backend exercises the sweep machinery in tests.
# ---------------------------------------------------------------------------


def _gemm_space(impl: str, interpret: bool) -> List[KernelConfig]:
    return [KernelConfig(impl=impl, interpret=interpret, block_m=bm,
                         block_n=bn, block_k=bk)
            for bm in (64, 128, 256)
            for bn in (128, 256)
            for bk in (128, 512)]


def _flash_space(impl: str, interpret: bool) -> List[KernelConfig]:
    return [KernelConfig(impl=impl, interpret=interpret, block_q=bq,
                         block_k=bk)
            for bq in (64, 128, 256)
            for bk in (128, 256)]


def config_space(op: str, backend: Optional[str] = None) -> List[KernelConfig]:
    bk = dispatch.backend() if backend is None else backend
    if bk == "cpu":
        if op == "flash_attention":
            # The XLA lowering itself has real knobs on CPU (ref.py,
            # 5-D grouped layout): the unnormalized-softmax rewrite and
            # the causal block skip. They compete against the plain
            # oracle; winners still pass validate() (the deviation is
            # one reassociation, ~1e-6, and the block skip is exact).
            return [dispatch.XLA] + [
                KernelConfig(impl="xla", fast_softmax=fs, causal_blocks=cb)
                for fs in (False, True) for cb in (0, 2, 4, 8)
                if fs or cb]
        return [dispatch.XLA]
    interp = bk == "interpret"
    impl = "pallas"
    if op in ("grouped_matmul", "grouped_matmul_armt_update"):
        space = _gemm_space(impl, interp)
        if op == "grouped_matmul_armt_update":
            space = [dataclasses.replace(c, block_n=0, fuse_epilogue=f)
                     for c in space for f in (True, False)]
            # dedup (block_n collapsed)
            space = list(dict.fromkeys(space))
    elif op in ("flash_attention", "decode_attention"):
        space = _flash_space(impl, interp)
        if op == "decode_attention":
            space = list(dict.fromkeys(
                dataclasses.replace(c, block_q=0) for c in space))
    elif op == "armt_read":
        space = [KernelConfig(impl=impl, interpret=interp, block_t=bt,
                              block_v=bv)
                 for bt in (128, 256) for bv in (256, 512)]
    elif op == "armt_update":
        space = [KernelConfig(impl=impl, interpret=interp, block_v=bv)
                 for bv in (256, 512)]
    elif op == "mamba_scan":
        space = [KernelConfig(impl=impl, interpret=interp, block_i=bi)
                 for bi in (128, 256, 512)]
    else:
        raise ValueError(f"unknown op {op!r}")
    if not interp:
        space = [dispatch.XLA] + space      # XLA-native always competes
    return space


# ---------------------------------------------------------------------------
# Op runners: name -> fn(args, config) (ops.py wrappers with config forced)
# ---------------------------------------------------------------------------

_RUNNERS: Dict[str, Callable[..., Any]] = {
    "grouped_matmul": lambda a, c, **kw: ops.grouped_gemm(*a, config=c, **kw),
    "grouped_matmul_armt_update":
        lambda a, c, **kw: ops.grouped_gemm_armt_update(*a, config=c, **kw),
    "flash_attention": lambda a, c, **kw: ops.segment_attention(
        *a, config=c, **kw),
    "decode_attention": lambda a, c, **kw: ops.decode_attention(
        *a, config=c, **kw),
    "armt_read": lambda a, c, **kw: ops.assoc_read(*a, config=c, **kw),
    "armt_update": lambda a, c, **kw: ops.assoc_update(*a, config=c, **kw),
    "mamba_scan": lambda a, c, **kw: ops.selective_scan_fused(
        *a, config=c, **kw),
}

def _flash_ref(q, k, v, **kw):
    # route by layout like ops.segment_attention: 5-D grouped operands
    # validate against the grouped oracle (default flags — the exact path)
    if q.ndim == 5:
        return ref.flash_attention_grouped_ref(q, k, v, **kw)
    return ref.flash_attention_ref(q, k, v, **kw)


_REFS: Dict[str, Callable[..., Any]] = {
    "grouped_matmul": ref.grouped_matmul_ref,
    "grouped_matmul_armt_update": ref.grouped_matmul_armt_update_ref,
    "flash_attention": _flash_ref,
    "decode_attention": ref.decode_attention_ref,
    "armt_read": ref.armt_read_ref,
    "armt_update": ref.armt_update_ref,
    "mamba_scan": ref.mamba_scan_ref,
}

# key shapes for the dispatch cache key, per op: indices of args whose
# shapes key the bucket (matches what ops.py passes to resolve())
_KEY_ARGS: Dict[str, Tuple[int, ...]] = {
    "grouped_matmul": (0, 1),
    "grouped_matmul_armt_update": (0, 1, 6),
    "flash_attention": (0, 1),
    "decode_attention": (0, 1),
    "armt_read": (0, 2),
    "armt_update": (0, 4),
    "mamba_scan": (0, 2),
}


def run_op(op: str, args: Sequence[Any], config: KernelConfig, **kw):
    return _RUNNERS[op](tuple(args), config, **kw)


class Autotuner:
    """Sweeps config spaces and fills the dispatch cache.

    ``cache_path=None`` uses the dispatch layer's default disk location;
    pass an explicit path in tests. ``persist=False`` keeps winners
    in-memory only (the dispatch table still serves them this process).
    """

    def __init__(self, cache_path: Optional[str] = None, *,
                 registry=None, persist: bool = True):
        if cache_path is not None:
            dispatch.set_cache_path(cache_path)
        self.persist = persist
        if registry is None:
            from repro.serve.telemetry import default_registry
            registry = default_registry()
        self.registry = registry

    # -- timing ---------------------------------------------------------

    @staticmethod
    def _time_once(fn: Callable[[], Any]) -> float:
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        return time.perf_counter() - t0

    def sweep(self, op: str, args: Sequence[Any], *,
              backend: Optional[str] = None, repeats: int = 3,
              op_kwargs: Optional[Dict[str, Any]] = None
              ) -> List[Tuple[KernelConfig, float]]:
        """Time every candidate, paired: one warmup (compile) per
        candidate, then ``repeats`` rounds visiting every candidate per
        round. Returns (config, best_seconds) sorted fastest-first;
        candidates that fail to lower/validate shape constraints are
        dropped."""
        kw = op_kwargs or {}
        args = tuple(args)
        cands: List[KernelConfig] = []
        fns: List[Callable[[], Any]] = []
        times: List[List[float]] = []
        for cand in config_space(op, backend):
            # jit the whole closure so XLA-native candidates compete as a
            # compiled program, not an eager jnp trace per call; operands
            # stay jit *arguments* (a zero-arg closure would let XLA
            # constant-fold the op away and time nothing)
            jitted = jax.jit(lambda *a, c=cand: run_op(op, a, c, **kw))
            fn = lambda f=jitted: f(*args)
            try:
                jax.block_until_ready(fn())
            except Exception:
                continue                     # unlowerable on these shapes
            cands.append(cand)
            fns.append(fn)
            times.append([])
        for _ in range(repeats):
            for i, fn in enumerate(fns):
                times[i].append(self._time_once(fn))
                self.registry.inc("autotune_sweep_total", op=op)
        ranked = sorted(zip(cands, (min(ts) for ts in times)),
                        key=lambda p: p[1])
        return ranked

    # -- validation -----------------------------------------------------

    def validate(self, op: str, args: Sequence[Any], config: KernelConfig,
                 *, op_kwargs: Optional[Dict[str, Any]] = None,
                 atol: float = 2e-4, rtol: float = 2e-3) -> bool:
        """Bit-validate ``config`` against the jnp oracle: pallas configs
        run in interpret mode (the kernel body, exactly, on CPU)."""
        kw = op_kwargs or {}
        cfg = (dataclasses.replace(config, interpret=True)
               if config.impl == "pallas" else config)
        got = run_op(op, args, cfg, **kw)
        want = _REFS[op](*args, **kw)
        try:
            jax.tree_util.tree_map(
                lambda a, b: np.testing.assert_allclose(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    atol=atol, rtol=rtol), got, want)
            ok = True
        except AssertionError:
            ok = False
        self.registry.inc("autotune_validate_total", op=op,
                          result="pass" if ok else "fail")
        return ok

    # -- the public entry ----------------------------------------------

    def key_for(self, op: str, args: Sequence[Any],
                backend: Optional[str] = None) -> str:
        shapes = [tuple(args[i].shape) for i in _KEY_ARGS[op]]
        bk = dispatch.backend() if backend is None else backend
        return dispatch.cache_key(bk, op, shapes, args[0].dtype)

    def get_or_tune(self, op: str, args: Sequence[Any], *,
                    backend: Optional[str] = None, repeats: int = 3,
                    op_kwargs: Optional[Dict[str, Any]] = None
                    ) -> KernelConfig:
        """Warm path: cached winner, zero launches. Cold path: sweep,
        validate the winner (falling through to the next-fastest candidate
        on a validation failure), store, return."""
        key = self.key_for(op, args, backend)
        hit = dispatch.cached_config(key)
        if hit is not None:
            self.registry.inc("autotune_cache_hit_total", op=op)
            return hit
        ranked = self.sweep(op, args, backend=backend, repeats=repeats,
                            op_kwargs=op_kwargs)
        if not ranked:
            return dispatch.heuristic(op, backend)
        for cand, _t in ranked:
            if self.validate(op, args, cand, op_kwargs=op_kwargs):
                dispatch.store_config(key, cand, persist=self.persist)
                return cand
        return dispatch.heuristic(op, backend)
