"""Backend dispatch for every kernel entry point (DESIGN.md §14).

One resolver decides, per op call, *which implementation* runs (Pallas
kernel vs. XLA-native jnp oracle) and *with which tuning config* (block
sizes, interpret-mode lowering, epilogue fusion) — so ``forward_hidden``,
the fused grouped-block path, and the serving scheduler's pooled launches
all make the same decision through one table instead of scattered
``on_tpu()`` checks.

Resolution order (``resolve``):

1. explicit per-call override (``use_kernel`` / ``interpret`` kwargs — the
   historical ops.py convention, kept verbatim so tests can force the
   kernel bodies in interpret mode on CPU);
2. the autotune cache — winners measured offline by
   :mod:`repro.kernels.autotune`, keyed ``(backend, op, shape-bucket,
   dtype)`` and persisted to disk (``set_cache_path`` /
   ``REPRO_KERNEL_CACHE``);
3. the static per-backend heuristic table (``HEURISTICS``) — the
   cold-start default: CPU dispatches to XLA-native (the jnp oracle beats
   pallas-interpret by orders of magnitude there), TPU/GPU dispatch to the
   Pallas kernels with MXU/SM-sized blocks.

``resolve`` is called at *trace time* (the ops wrappers run inside jit),
so it must stay pure-static: a dict lookup, no timing, no device work.
Sweeps happen strictly offline in autotune.py. Each resolution increments
``kernel_dispatch_total{op,impl,backend,source}`` in the serve-stack
metrics registry — once per compiled specialization, which is exactly the
cardinality a dispatch counter should have.

Shape bucketing: every dim is rounded up to a power of two, so one tuned
config serves e.g. all of M in (65..128] — the diagonal executor's grouped
shapes span three orders of magnitude (1-token decode cells to 1M-token
prefill), and exact-shape keys would never hit.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Iterable, Optional, Tuple

import jax

OPS = (
    "grouped_matmul",
    "grouped_matmul_armt_update",
    "flash_attention",
    "decode_attention",
    "armt_read",
    "armt_update",
    "mamba_scan",
)

BACKENDS = ("cpu", "gpu", "tpu", "interpret")


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One dispatch decision: implementation + tuning knobs.

    ``impl`` picks the lowering: ``"xla"`` = the jnp oracle in
    kernels/ref.py (XLA fuses it natively — the CPU fast path and the
    autodiff path), ``"pallas"`` = the hand-tiled kernel.

    Block fields are 0 when unused by the op or "kernel default"; each
    ops.py wrapper forwards only the fields its kernel accepts
    (``blocks()``). ``fuse_epilogue`` gates the ARMT-update-into-GEMM
    fusion (grouped_matmul_armt_update) — tunable because the fused
    kernel constrains tiling (full-width N) and can lose on some shapes.
    """
    impl: str = "xla"            # xla | pallas
    interpret: bool = False      # pallas: interpret-mode (CPU validation)
    block_m: int = 0
    block_n: int = 0
    block_k: int = 0
    block_q: int = 0
    block_t: int = 0
    block_v: int = 0
    block_i: int = 0
    fuse_epilogue: bool = True
    # flash_attention, xla impl only: unnormalized-softmax lowering (divide
    # the value-matmul output instead of the score-sized probability
    # tensor). Reassociates the normalizer, so it is never part of the
    # exactness-oracle config (use_kernel=False) — only heuristics and
    # autotuned winners may switch it on; bit-validation against the
    # oracle is tests/test_kernel_dispatch.py.
    fast_softmax: bool = False
    # flash_attention, xla impl only: split the causal square into query
    # halves and skip the fully-masked upper-right score quadrant (exact —
    # the skipped softmax terms are hard zeros). 0 = off.
    causal_blocks: int = 0

    def blocks(self, *names: str) -> Dict[str, int]:
        """The requested block fields that are set (nonzero)."""
        out = {}
        for n in names:
            v = getattr(self, n)
            if v:
                out[n] = v
        return out

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "KernelConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


XLA = KernelConfig(impl="xla")
PALLAS = KernelConfig(impl="pallas")
PALLAS_INTERPRET = KernelConfig(impl="pallas", interpret=True)


def backend() -> str:
    """The active JAX backend as a heuristic-table key."""
    b = jax.default_backend()
    return b if b in ("cpu", "gpu", "tpu") else "cpu"


# ---------------------------------------------------------------------------
# Cold-start heuristic table
# ---------------------------------------------------------------------------
# (backend, op) -> KernelConfig. Unlisted (backend, op) pairs fall back to
# the backend default: cpu -> XLA, tpu/gpu -> PALLAS with kernel defaults,
# interpret -> PALLAS_INTERPRET. Kernel-default block sizes live in the
# kernel signatures (grouped_matmul.py etc.); entries here override them
# where the generic default is known-bad for a backend.

_BACKEND_DEFAULT = {
    "cpu": XLA,
    "gpu": PALLAS,
    "tpu": PALLAS,
    "interpret": PALLAS_INTERPRET,
}

HEURISTICS: Dict[Tuple[str, str], KernelConfig] = {
    # TPU: MXU-native 128 lanes; deep K accumulation amortizes the revisit.
    ("tpu", "grouped_matmul"): KernelConfig(
        impl="pallas", block_m=128, block_n=128, block_k=512),
    ("tpu", "grouped_matmul_armt_update"): KernelConfig(
        impl="pallas", block_m=256, block_k=512),
    ("tpu", "flash_attention"): KernelConfig(
        impl="pallas", block_q=128, block_k=128),
    ("tpu", "decode_attention"): KernelConfig(impl="pallas", block_k=128),
    ("tpu", "armt_read"): KernelConfig(
        impl="pallas", block_t=256, block_v=512),
    ("tpu", "armt_update"): KernelConfig(impl="pallas", block_v=512),
    ("tpu", "mamba_scan"): KernelConfig(impl="pallas", block_i=512),
    # GPU: smaller K tiles (SMEM pressure), everything else kernel-default.
    ("gpu", "grouped_matmul"): KernelConfig(
        impl="pallas", block_m=64, block_n=128, block_k=64),
    ("gpu", "flash_attention"): KernelConfig(
        impl="pallas", block_q=64, block_k=64),
    ("gpu", "decode_attention"): KernelConfig(impl="pallas", block_k=128),
    # CPU: XLA-native everywhere — pallas-interpret is a validation tool,
    # not an execution engine (orders of magnitude slower than fused XLA).
    # Attention additionally takes the unnormalized-softmax lowering (one
    # fewer pass over the score-sized tensor) and the causal quadrant skip
    # — measurably faster on the memory-bound CPU backend
    # (EXPERIMENTS.md §Kernels).
    ("cpu", "flash_attention"): KernelConfig(
        impl="xla", fast_softmax=True, causal_blocks=4),
}


def heuristic(op: str, bk: Optional[str] = None) -> KernelConfig:
    bk = backend() if bk is None else bk
    return HEURISTICS.get((bk, op), _BACKEND_DEFAULT[bk])


# ---------------------------------------------------------------------------
# Autotune cache (disk-backed, loaded lazily, written by autotune.py)
# ---------------------------------------------------------------------------

_cache: Optional[Dict[str, KernelConfig]] = None
_cache_path: Optional[str] = None


def default_cache_path() -> str:
    return os.environ.get(
        "REPRO_KERNEL_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "kernel_cache.json"))


def set_cache_path(path: Optional[str]) -> None:
    """Point the dispatch layer at a cache file (None -> default path).
    Drops the in-memory table so the next resolve reloads."""
    global _cache_path, _cache
    _cache_path = path
    _cache = None


def _load_cache() -> Dict[str, KernelConfig]:
    global _cache
    if _cache is None:
        path = _cache_path or default_cache_path()
        _cache = {}
        if os.path.exists(path):
            try:
                with open(path) as f:
                    raw = json.load(f)
                _cache = {k: KernelConfig.from_json(v)
                          for k, v in raw.get("configs", {}).items()}
            except (OSError, ValueError, TypeError):
                _cache = {}
    return _cache


def store_config(key: str, cfg: KernelConfig, persist: bool = True) -> None:
    """Install an autotuned winner (autotune.py's write path)."""
    cache = _load_cache()
    cache[key] = cfg
    if persist:
        path = _cache_path or default_cache_path()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({"configs": {k: v.to_json()
                                   for k, v in cache.items()}}, f, indent=1)


def cached_config(key: str) -> Optional[KernelConfig]:
    return _load_cache().get(key)


# ---------------------------------------------------------------------------
# Shape bucketing + cache keys
# ---------------------------------------------------------------------------

def _pow2(n: int) -> int:
    return 1 << (int(n) - 1).bit_length() if n > 1 else 1


def shape_bucket(shapes: Iterable[Tuple[int, ...]]) -> Tuple[Tuple[int, ...],
                                                             ...]:
    """Pow2-round every dim of every operand shape."""
    return tuple(tuple(_pow2(d) for d in s) for s in shapes)


def cache_key(bk: str, op: str, shapes: Iterable[Tuple[int, ...]],
              dtype) -> str:
    bucket = shape_bucket(shapes)
    bs = "x".join("_".join(map(str, s)) for s in bucket)
    return f"{bk}/{op}/{bs}/{jax.numpy.dtype(dtype).name}"


# ---------------------------------------------------------------------------
# The resolver
# ---------------------------------------------------------------------------

def _registry():
    # lazy: kernels must not import the serve stack at module load
    from repro.serve.telemetry import default_registry
    return default_registry()


def resolve(op: str, shapes: Iterable[Tuple[int, ...]], dtype, *,
            use_kernel: Optional[bool] = None,
            interpret: Optional[bool] = None,
            kernel_backend: Optional[str] = None) -> KernelConfig:
    """Pick the KernelConfig for one op call. Pure static — safe at trace
    time. ``use_kernel``/``interpret`` are the historical per-call
    overrides; ``kernel_backend`` is the config-level knob
    (ArchConfig.kernel_backend): 'auto' | 'xla' | 'pallas' |
    'pallas_interpret'."""
    assert op in OPS, op
    shapes = tuple(tuple(s) for s in shapes)
    if use_kernel is None and kernel_backend and kernel_backend != "auto":
        use_kernel = kernel_backend != "xla"
        if interpret is None and kernel_backend == "pallas_interpret":
            interpret = True
    if use_kernel is not None:
        if not use_kernel:
            cfg, source = XLA, "override"
        else:
            base = heuristic(op, "tpu" if backend() == "cpu" else backend())
            cfg = dataclasses.replace(base, impl="pallas",
                                      interpret=bool(interpret))
            source = "override"
    else:
        bk = backend()
        key = cache_key(bk, op, shapes, dtype)
        hit = cached_config(key)
        if hit is not None:
            cfg, source = hit, "cache"
        else:
            cfg, source = heuristic(op, bk), "heuristic"
    _registry().inc("kernel_dispatch_total", op=op, impl=cfg.impl,
                    backend=backend(), source=source)
    return cfg
