"""Mamba-1 selective scan — fused Pallas TPU kernel.

The faithful mamba-1 recurrence is sequential in time; the CUDA kernel's win
is keeping h resident in SRAM. TPU analogue: grid over (batch, d_inner
blocks); per program the state h [block_i, dS] lives in VMEM scratch and the
time loop streams x/dt/B/C tiles — h never touches HBM.

h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) * B_t ;  y_t = h_t . C_t + D*x_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, alog_ref, d_ref, h0_ref,
                 y_ref, hout_ref, h_scr, *, T: int):
    # x/dt: [T, bi]; b/c: [T, dS]; alog: [bi, dS]; d: [bi]; h0: [bi, dS]
    A = -jnp.exp(alog_ref[...].astype(jnp.float32))          # [bi, dS]
    D = d_ref[...].astype(jnp.float32)
    h_scr[...] = h0_ref[...].astype(jnp.float32)

    def step(t, _):
        x_t = x_ref[t, :].astype(jnp.float32)                # [bi]
        dt_t = dt_ref[t, :].astype(jnp.float32)              # [bi]
        B_t = b_ref[t, :].astype(jnp.float32)                # [dS]
        C_t = c_ref[t, :].astype(jnp.float32)                # [dS]
        da = jnp.exp(dt_t[:, None] * A)                      # [bi, dS]
        h = da * h_scr[...] + (dt_t * x_t)[:, None] * B_t[None, :]
        h_scr[...] = h
        y = h @ C_t + D * x_t                                # [bi]
        y_ref[t, :] = y.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, T, step, 0)
    hout_ref[...] = h_scr[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_i", "interpret"))
def mamba_scan(x, dt, Bt, Ct, A_log, D, h0, *, block_i: int = 512,
               interpret: bool = False):
    """x/dt: [B,T,dI]; Bt/Ct: [B,T,dS]; A_log: [dI,dS]; D: [dI];
    h0: [B,dI,dS] -> (y [B,T,dI] fp32, hT [B,dI,dS] fp32)."""
    B, T, dI = x.shape
    dS = Bt.shape[-1]
    block_i = min(block_i, dI)
    grid = (B, pl.cdiv(dI, block_i))
    y, hT = pl.pallas_call(
        functools.partial(_scan_kernel, T=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, T, block_i), lambda b, ii: (b, 0, ii)),
            pl.BlockSpec((None, T, block_i), lambda b, ii: (b, 0, ii)),
            pl.BlockSpec((None, T, dS), lambda b, ii: (b, 0, 0)),
            pl.BlockSpec((None, T, dS), lambda b, ii: (b, 0, 0)),
            pl.BlockSpec((block_i, dS), lambda b, ii: (ii, 0)),
            pl.BlockSpec((block_i,), lambda b, ii: (ii,)),
            pl.BlockSpec((None, block_i, dS), lambda b, ii: (b, ii, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, T, block_i), lambda b, ii: (b, 0, ii)),
            pl.BlockSpec((None, block_i, dS), lambda b, ii: (b, ii, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, dI), jnp.float32),
            jax.ShapeDtypeStruct((B, dI, dS), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_i, dS), jnp.float32)],
        interpret=interpret,
    )(x, dt, Bt, Ct, A_log, D, h0)
    return y, hT
