"""Pallas TPU kernels for the paper's hot spots (validated in interpret mode):

  flash_attention  — grouped/batched flash attention (paper §4.2: attention
                     batched over the diagonal group dim)
  decode_attention — single-token decode against the serve KV cache
                     (dynamic-length block skip; the serve hot path)
  grouped_matmul   — batched GEMM with VMEM tiling (paper §3.3 GroupedGEMM)
                     + the fused ARMT-memory-update epilogue variant
  armt_memory      — fused associative read + delta-rule update (eqs. 3-6)
  mamba_scan       — fused selective scan, h resident in VMEM

``ops`` contains the jit'd entry points, routed through ``dispatch``
(per-backend impl + tuning-config resolver; DESIGN.md §14); ``autotune``
fills the dispatch cache offline; ``ref`` contains the pure-jnp oracles
used by the allclose test sweeps.
"""
from repro.kernels import dispatch, ops, ref
