"""Pallas TPU kernels for the paper's hot spots (validated in interpret mode):

  flash_attention — grouped/batched flash attention (paper §4.2: attention
                    batched over the diagonal group dim)
  grouped_matmul  — batched GEMM with VMEM tiling (paper §3.3 GroupedGEMM)
  armt_memory     — fused associative read + delta-rule update (eqs. 3-6)
  mamba_scan      — fused selective scan, h resident in VMEM

``ops`` contains jit'd dispatch wrappers (kernel on TPU, jnp oracle on CPU);
``ref`` contains the pure-jnp oracles used by the allclose test sweeps.
"""
from repro.kernels import ops, ref
