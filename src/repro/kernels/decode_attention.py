"""Single-token decode attention — Pallas TPU kernel (the serve hot path).

One query token per row against a paged-in KV cache prefix: q [B, Hq, hd]
vs. cache k/v [B, S, Hkv, hd] (the serve-stack cache layout — no
transpose on the way in) with per-row valid lengths [B]. Grid (B, Hq);
online softmax streams the cache in [block_k, hd] tiles and the time loop
stops at the row's length (``fori_loop`` upper bound is dynamic — blocks
past the valid prefix are never touched, so a 32-token-deep slot in a
64k-slot cache reads one tile, not 512).

GQA via the BlockSpec index map (kv head = q head // rep), like
flash_attention.py. Sliding window masks keys below ``qpos - window``
(qpos = length - 1) — decode is causal by construction, so there is no
upper bound to mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, sm_scale: float,
                   window: int, block_k: int):
    # q: [hd]; k/v: [S, hd]; len: [1]; o: [hd]
    hd = q_ref.shape[0]
    S = k_ref.shape[0]
    length = len_ref[0]
    q = q_ref[...].astype(jnp.float32) * sm_scale

    def body(ik, carry):
        m_i, l_i, acc = carry
        start_k = ik * block_k
        k = k_ref[pl.dslice(start_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.dslice(start_k, block_k), :].astype(jnp.float32)
        s = k @ q                                            # [bk]
        k_pos = start_k + jax.lax.iota(jnp.int32, block_k)
        mask = k_pos < length
        if window > 0:
            mask &= k_pos > (length - 1 - window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_i, s.max())
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + p.sum()
        acc_new = acc * alpha + p @ v
        return m_new, l_new, acc_new

    if window > 0:
        k_start = jnp.maximum(0, (length - window) // block_k)
    else:
        k_start = 0
    n_k_eff = jnp.minimum(pl.cdiv(S, block_k),
                          pl.cdiv(length, block_k))
    m_i, l_i, acc = jax.lax.fori_loop(
        k_start, n_k_eff, body,
        (jnp.float32(NEG_INF), jnp.float32(0.0), jnp.zeros((hd,), jnp.float32)))
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(q, k, v, lengths, *, window: int = 0,
                     block_k: int = 128, interpret: bool = False):
    """q: [B, Hq, hd]; k/v: [B, S, Hkv, hd] (cache layout); lengths: [B]
    int32 (valid prefix incl. the current token) -> [B, Hq, hd]."""
    B, Hq, hd = q.shape
    _, S, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    rep = Hq // Hkv
    block_k = min(block_k, S)
    sm_scale = hd ** -0.5
    lengths = lengths.astype(jnp.int32).reshape(B, 1)

    # zero-pad a ragged cache length so the last dslice tile is not read
    # through clamping; pad keys are masked via the per-row length
    S_pad = pl.cdiv(S, block_k) * block_k
    if S_pad != S:
        k = jnp.pad(k, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
        S = S_pad

    grid = (B, Hq)
    kernel = functools.partial(_decode_kernel, sm_scale=sm_scale,
                               window=window, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, hd), lambda b, h: (b, h, 0)),
            pl.BlockSpec((None, S, None, hd), lambda b, h: (b, 0, h // rep, 0)),
            pl.BlockSpec((None, S, None, hd), lambda b, h: (b, 0, h // rep, 0)),
            pl.BlockSpec((None, 1), lambda b, h: (b, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, hd), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v, lengths)
