"""Grouped flash attention — Pallas TPU kernel.

The diagonal-batching hot spot: attention over a *group* of layer-slots
(paper §4.2 batches attention across the group dim to reach batch-scaling
FLOPs). Layout: q [N, Hq, T, hd], k/v [N, Hkv, S, hd] where N = group*batch.
GQA is handled by the BlockSpec index map (kv head = q head // rep) — no
materialized head repetition. Causal and sliding-window masks supported.

VMEM tiling: queries in [block_q, hd] tiles; K/V streamed in [block_k, hd]
tiles with online softmax (running max/sum), fp32 accumulators. hd is padded
to the 128-lane MXU width by the wrapper (ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale: float,
                  causal: bool, window: int, block_k: int, kv_len: int):
    # q_ref: [block_q, hd]; k_ref/v_ref: [kv_len, hd]; o_ref: [block_q, hd]
    block_q, hd = q_ref.shape
    start_q = pl.program_id(2) * block_q

    q = q_ref[...].astype(jnp.float32) * sm_scale
    m_i = jnp.full((block_q,), NEG_INF, jnp.float32)
    l_i = jnp.zeros((block_q,), jnp.float32)
    acc = jnp.zeros((block_q, hd), jnp.float32)

    q_pos = start_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    def body(ik, carry):
        m_i, l_i, acc = carry
        start_k = ik * block_k
        k = k_ref[pl.dslice(start_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.dslice(start_k, block_k), :].astype(jnp.float32)
        s = q @ k.T                                        # [bq, bk]
        k_pos = start_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > (q_pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_i, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    n_k = pl.cdiv(kv_len, block_k)
    if causal:
        # skip fully-masked k blocks beyond the diagonal
        n_k_eff = jnp.minimum(
            n_k, (start_q + block_q + block_k - 1) // block_k)
    else:
        n_k_eff = n_k
    if window > 0:
        # skip fully-masked k blocks below the sliding window: the earliest
        # key any query in this block attends to is start_q - window + 1
        k_start = jnp.maximum(0, (start_q - window + 1) // block_k)
    else:
        k_start = 0
    m_i, l_i, acc = jax.lax.fori_loop(k_start, n_k_eff, body, (m_i, l_i, acc))
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: [N, Hq, T, hd]; k/v: [N, Hkv, S, hd] -> [N, Hq, T, hd]."""
    N, Hq, T, hd = q.shape
    _, Hkv, S, _ = k.shape
    assert Hq % Hkv == 0
    rep = Hq // Hkv
    block_q = min(block_q, T)
    block_k = min(block_k, S)
    sm_scale = hd ** -0.5

    grid = (N, Hq, pl.cdiv(T, block_q))
    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, window=window,
        block_k=block_k, kv_len=S)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, hd),
                         lambda n, h, iq: (n, h, iq, 0)),
            pl.BlockSpec((None, None, S, hd),
                         lambda n, h, iq: (n, h // rep, 0, 0)),
            pl.BlockSpec((None, None, S, hd),
                         lambda n, h, iq: (n, h // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, hd),
                               lambda n, h, iq: (n, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
