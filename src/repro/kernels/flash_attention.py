"""Grouped flash attention — Pallas TPU kernel.

The diagonal-batching hot spot: attention over a *group* of layer-slots
(paper §4.2 batches attention across the group dim to reach batch-scaling
FLOPs). Layout: q [N, Hq, T, hd], k/v [N, Hkv, S, hd] where N = group*batch.
GQA is handled by the BlockSpec index map (kv head = q head // rep) — no
materialized head repetition. Causal and sliding-window masks supported.

VMEM tiling: queries in [block_q, hd] tiles; K/V streamed in [block_k, hd]
tiles with online softmax (running max/sum), fp32 accumulators. hd is padded
to the 128-lane MXU width by the wrapper (ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale: float,
                  causal: bool, window: int, block_k: int, kv_len: int,
                  skip_blocks: bool):
    # q_ref: [block_q, hd]; k_ref/v_ref: [kv_len, hd]; o_ref: [block_q, hd]
    block_q, hd = q_ref.shape
    start_q = pl.program_id(2) * block_q

    q = q_ref[...].astype(jnp.float32) * sm_scale
    m_i = jnp.full((block_q,), NEG_INF, jnp.float32)
    l_i = jnp.zeros((block_q,), jnp.float32)
    acc = jnp.zeros((block_q, hd), jnp.float32)

    q_pos = start_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    def body(ik, carry):
        m_i, l_i, acc = carry
        start_k = ik * block_k
        k = k_ref[pl.dslice(start_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.dslice(start_k, block_k), :].astype(jnp.float32)
        s = q @ k.T                                        # [bq, bk]
        k_pos = start_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > (q_pos - window)
            if not causal:
                # symmetric window: keys beyond qpos + window are masked
                # (causal mode already bounds above via k_pos <= q_pos)
                mask &= k_pos < (q_pos + window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_i, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    n_k = pl.cdiv(kv_len, block_k)
    if causal and skip_blocks:
        # skip fully-masked k blocks beyond the diagonal
        n_k_eff = jnp.minimum(
            n_k, (start_q + block_q + block_k - 1) // block_k)
    elif window > 0 and not causal and skip_blocks:
        # symmetric-window upper bound: the latest key any query in this
        # block attends to is start_q + block_q - 1 + window - 1
        n_k_eff = jnp.minimum(
            n_k, (start_q + block_q + window - 2) // block_k + 1)
    else:
        n_k_eff = n_k
    if window > 0 and skip_blocks:
        # skip fully-masked k blocks below the sliding window: the earliest
        # key any query in this block attends to is start_q - window + 1
        k_start = jnp.maximum(0, (start_q - window + 1) // block_k)
    else:
        k_start = 0
    m_i, l_i, acc = jax.lax.fori_loop(k_start, n_k_eff, body, (m_i, l_i, acc))
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret",
                     "skip_blocks"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False, skip_blocks: bool = True):
    """q: [N, Hq, T, hd]; k/v: [N, Hkv, S, hd] -> [N, Hq, T, hd].

    ``skip_blocks=False`` disables the causal / sliding-window block-skip
    bounds and visits every k tile, relying on the mask alone — the debug
    reference for the masked-vs-skipped equivalence test (the two must
    agree bitwise; a skipped block that wasn't fully masked would not)."""
    N, Hq, T, hd = q.shape
    _, Hkv, S, _ = k.shape
    assert Hq % Hkv == 0
    rep = Hq // Hkv
    block_q = min(block_q, T)
    block_k = min(block_k, S)
    sm_scale = hd ** -0.5

    # zero-pad ragged T/S up to a block multiple: the last k tile would
    # otherwise be read through a clamped dslice (shifted data under the
    # unshifted k_pos mask); pad keys are masked via the real kv_len and
    # pad query rows are sliced off below
    T_pad = pl.cdiv(T, block_q) * block_q
    S_pad = pl.cdiv(S, block_k) * block_k
    if T_pad != T:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, T_pad - T), (0, 0)))
    if S_pad != S:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, S_pad - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, S_pad - S), (0, 0)))

    grid = (N, Hq, T_pad // block_q)
    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, window=window,
        block_k=block_k, kv_len=S, skip_blocks=skip_blocks)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, hd),
                         lambda n, h, iq: (n, h, iq, 0)),
            pl.BlockSpec((None, None, S_pad, hd),
                         lambda n, h, iq: (n, h // rep, 0, 0)),
            pl.BlockSpec((None, None, S_pad, hd),
                         lambda n, h, iq: (n, h // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, hd),
                               lambda n, h, iq: (n, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :T, :] if T_pad != T else out
