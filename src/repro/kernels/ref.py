"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.memory import dpfp

EPS = 1e-6


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [N,Hq,T,hd]; k/v: [N,Hkv,S,hd] -> [N,Hq,T,hd], fp32 softmax."""
    N, Hq, T, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("nhtd,nhsd->nhts", q, k).astype(jnp.float32) * hd ** -0.5
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > (qpos - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("nhts,nhsd->nhtd", p, v.astype(jnp.float32)).astype(q.dtype)


def grouped_matmul_ref(x, w, bias=None, *, activation: str | None = None):
    """x: [G,M,K], w: [G,K,N] (+ bias [G,N]) -> [G,M,N] (fp32 accumulation,
    epilogue = bias add + activation in fp32, matching the Pallas kernel)."""
    acc = jnp.einsum("gmk,gkn->gmn", x.astype(jnp.float32),
                     w.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)[:, None, :]
    if activation == "silu":
        acc = acc * jax.nn.sigmoid(acc)
    elif activation == "gelu":
        acc = jax.nn.gelu(acc, approximate=True)
    else:
        assert activation is None, activation
    return acc.astype(x.dtype)


def _proj(x, w):
    """x: [N,T,D] @ w: [D,E] (shared) or [G,D,E] (per-group, N = G*batch)."""
    if w.ndim == 2:
        return jnp.einsum("ntd,de->nte", x, w)
    G = w.shape[0]
    N = x.shape[0]
    xg = x.reshape((G, N // G) + x.shape[1:])
    out = jnp.einsum("gbtd,gde->gbte", xg, w)
    return out.reshape((N,) + out.shape[2:])


def armt_read_ref(x, wq, A, z, *, nu: int = 3):
    """x: [N,T,D]; wq: [D,dm] or [G,D,dm]; A: [N,P,Dv]; z: [N,P] -> [N,T,Dv]."""
    q = _proj(x.astype(jnp.float32), wq.astype(jnp.float32))
    pq = dpfp(q, nu)
    num = jnp.einsum("ntp,npv->ntv", pq, A.astype(jnp.float32))
    den = jnp.einsum("ntp,np->nt", pq, z.astype(jnp.float32)) + EPS
    return (num / den[..., None]).astype(x.dtype)


def armt_update_ref(m, wk, wv, wb, A, z, *, nu: int = 3):
    """m: [N,M,D]; wk/wv/wb: [D,*] (shared) or [G,D,*] (per-group)."""
    m32 = m.astype(jnp.float32)
    k = _proj(m32, wk.astype(jnp.float32))
    v = _proj(m32, wv.astype(jnp.float32))
    beta = jax.nn.sigmoid(_proj(m32, wb.astype(jnp.float32)))[..., 0]
    pk = dpfp(k, nu)
    zk = jnp.einsum("nmp,np->nm", pk, z.astype(jnp.float32))
    vbar = jnp.einsum("nmp,npv->nmv", pk, A.astype(jnp.float32)) \
        / (zk + EPS)[..., None]
    gamma = 1.0 - zk / (jnp.sum(pk * pk, axis=-1) + EPS)
    A_new = A.astype(jnp.float32) + jnp.einsum("nm,nmv,nmp->npv",
                                               beta, v - vbar, pk)
    z_new = z.astype(jnp.float32) + jnp.einsum("nm,nmp->np", gamma, pk)
    return A_new.astype(A.dtype), z_new.astype(z.dtype)


def mamba_scan_ref(x, dt, Bt, Ct, A_log, D, h0):
    """Token-sequential reference (fp32)."""
    A = -jnp.exp(A_log.astype(jnp.float32))

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        da = jnp.exp(dt_t[..., None] * A)
        h = da * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bis,bs->bi", h, C_t) + D * x_t
        return h, y

    xs = (x.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          Bt.swapaxes(0, 1).astype(jnp.float32),
          Ct.swapaxes(0, 1).astype(jnp.float32))
    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1), hT
