"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.memory import dpfp

EPS = 1e-6


@functools.lru_cache(maxsize=64)
def _attn_bias_cached(T: int, S: int, causal: bool, window: int):
    """Additive fp32 attention bias [1,1,T,S] (0 valid / -1e30 masked),
    computed eagerly once per shape and cached — embeds as one on-device
    constant shared by every compiled diagonal step body instead of being
    re-materialized per step (the [T,S] tensor is past XLA's constant-
    folding size cap, so in-graph construction really runs each step)."""
    with jax.ensure_compile_time_eval():
        qpos = jnp.arange(T)[:, None]
        kpos = jnp.arange(S)[None, :]
        mask = jnp.ones((T, S), bool)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > (qpos - window)
            if not causal:
                mask &= kpos < (qpos + window)
        return jnp.where(mask, 0.0, -1e30).astype(jnp.float32)[None, None]


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [N,Hq,T,hd]; k/v: [N,Hkv,S,hd] -> [N,Hq,T,hd], fp32 softmax."""
    N, Hq, T, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("nhtd,nhsd->nhts", q, k).astype(jnp.float32) * hd ** -0.5
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > (qpos - window)
        if not causal:
            # symmetric window: bounded above as well (causal mode is
            # already bounded above by the diagonal)
            mask &= kpos < (qpos + window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("nhts,nhsd->nhtd", p, v.astype(jnp.float32)).astype(q.dtype)


def flash_attention_grouped_ref(q, k, v, *, causal: bool = True,
                                window: int = 0,
                                fast_softmax: bool = False,
                                causal_blocks: int = 0):
    """q/k/v: [G, B, T, H, hd] (the grouped-block layout, kept 5-D) ->
    [G, B, T, Hq, hd]. Same math as flash_attention_ref, lowered with
    (g, b, h) batch dims — on CPU XLA schedules this form markedly faster
    than the flattened [N, H, T, S] contraction (BENCH_diagonal).

    The mask enters as an additive fp32 bias (0 / -1e30) fused into the
    score epilogue instead of a `where` select — one fewer full pass over
    the [*, T, S] score tensor, and still bit-identical to the select form
    (adding 0.0 is exact; -1e30 + O(scores) rounds back to -1e30).

    ``fast_softmax`` applies the normalizer to the [*, T, hd] *output* of
    the value matmul instead of the [*, T, S] probability tensor — one
    fewer full pass over the score-sized tensor, exact up to fp
    reassociation (max-subtraction keeps it overflow-safe). It is a
    *dispatched* lowering (the CPU heuristic turns it on, see
    kernels/dispatch.py); the default keeps the `jax.nn.softmax`
    association so the oracle path stays fp32-ulp-equal to the vmap
    executor (tests/test_grouped_blocks.py::test_fused_structure_is_exact).

    ``causal_blocks = n`` (dispatched the same way) splits the query range
    into n bands and, per band, only computes scores against the keys the
    causal mask can reach — the skipped blocks are *fully masked*, and
    their softmax terms would have been exact zeros (exp(-1e30 - m) ==
    0.0, and p * v contributes exact 0.0), so the blocked lowering is
    value-identical while saving (n-1)/(2n) of the score-tensor matmul,
    exp and weighted-sum work. Engaged only for the plain causal square
    case (no window) when n divides T."""
    G, B, T, Hq, hd = q.shape
    S, Hkv = k.shape[2], k.shape[3]
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=3)
        v = jnp.repeat(v, rep, axis=3)
    bias = _attn_bias_cached(T, S, causal, window)
    scale = hd ** -0.5
    nb = int(causal_blocks or 0)
    if not (nb > 1 and causal and window == 0 and T == S
            and T % nb == 0 and T // nb >= 8):
        nb = 0

    def attend(q, k, v, bb):                # [B,*,H,hd], bias slice
        s = jnp.einsum("bthd,bshd->bhts", q, k).astype(jnp.float32)
        s = s * scale + bb
        if fast_softmax:
            m = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m)              # unnormalized probabilities
            o = jnp.einsum("bhts,bshd->bthd", p, v.astype(jnp.float32))
            l = jnp.sum(p, axis=-1)         # [B,H,T]
            return o / l.swapaxes(1, 2)[..., None]
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhts,bshd->bthd", p, v.astype(jnp.float32))

    def one(q, k, v):                       # [B, T, H, hd] per group
        if not nb:
            return attend(q, k, v, bias)
        h = T // nb
        outs = [attend(q[:, i * h:(i + 1) * h],
                       k[:, :(i + 1) * h], v[:, :(i + 1) * h],
                       bias[:, :, i * h:(i + 1) * h, :(i + 1) * h])
                for i in range(nb)]
        return jnp.concatenate(outs, axis=1)

    # vmap over the group dim rather than one 5-D einsum: XLA (notably on
    # CPU) schedules the vmapped batched dot measurably faster, and it is
    # the exact dot shape the vmap executor path produces
    return jax.vmap(one)(q, k, v).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, *, window: int = 0):
    """q: [B,Hq,hd]; k/v: [B,S,Hkv,hd] (cache layout); lengths: [B] ->
    [B,Hq,hd], fp32 softmax over the valid prefix per row."""
    B, Hq, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    kh = jnp.repeat(k.swapaxes(1, 2), rep, axis=1)       # [B,Hq,S,hd]
    vh = jnp.repeat(v.swapaxes(1, 2), rep, axis=1)
    s = jnp.einsum("bhd,bhsd->bhs", q, kh).astype(jnp.float32) * hd ** -0.5
    kpos = jnp.arange(S)[None, None, :]
    lens = lengths.astype(jnp.int32)[:, None, None]
    mask = kpos < lens
    if window > 0:
        mask &= kpos > (lens - 1 - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, vh.astype(jnp.float32)).astype(q.dtype)


def grouped_matmul_ref(x, w, bias=None, *, activation: str | None = None):
    """x: [G,M,K] or [G,B,T,K], w: [G,K,N] (+ bias [G,N]) -> [G,(B,T|M),N]
    (fp32 accumulation, epilogue = bias add + activation in fp32, matching
    the Pallas kernel). The 4-D form keeps the grouped-block row dims
    un-flattened — on CPU XLA the `gbtk,gkn` contraction schedules ~2x
    faster inside composed graphs than the flattened `gmk,gkn` form
    (EXPERIMENTS.md §Kernels), and the two are value-identical."""
    if x.ndim == 4:
        acc = jnp.einsum("gbtk,gkn->gbtn", x.astype(jnp.float32),
                         w.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        if bias is not None:
            acc = acc + bias.astype(jnp.float32)[:, None, None, :]
    else:
        acc = jnp.einsum("gmk,gkn->gmn", x.astype(jnp.float32),
                         w.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        if bias is not None:
            acc = acc + bias.astype(jnp.float32)[:, None, :]
    if activation == "silu":
        acc = acc * jax.nn.sigmoid(acc)
    elif activation == "gelu":
        acc = jax.nn.gelu(acc, approximate=True)
    else:
        assert activation is None, activation
    return acc.astype(x.dtype)


def _proj(x, w):
    """x: [N,T,D] @ w: [D,E] (shared) or [G,D,E] (per-group, N = G*batch)."""
    if w.ndim == 2:
        return jnp.einsum("ntd,de->nte", x, w)
    G = w.shape[0]
    N = x.shape[0]
    xg = x.reshape((G, N // G) + x.shape[1:])
    out = jnp.einsum("gbtd,gde->gbte", xg, w)
    return out.reshape((N,) + out.shape[2:])


def armt_read_ref(x, wq, A, z, *, nu: int = 3):
    """x: [N,T,D]; wq: [D,dm] or [G,D,dm]; A: [N,P,Dv]; z: [N,P] -> [N,T,Dv]."""
    q = _proj(x.astype(jnp.float32), wq.astype(jnp.float32))
    pq = dpfp(q, nu)
    num = jnp.einsum("ntp,npv->ntv", pq, A.astype(jnp.float32))
    den = jnp.einsum("ntp,np->nt", pq, z.astype(jnp.float32)) + EPS
    return (num / den[..., None]).astype(x.dtype)


def armt_update_ref(m, wk, wv, wb, A, z, *, nu: int = 3):
    """m: [N,M,D]; wk/wv/wb: [D,*] (shared) or [G,D,*] (per-group)."""
    m32 = m.astype(jnp.float32)
    k = _proj(m32, wk.astype(jnp.float32))
    v = _proj(m32, wv.astype(jnp.float32))
    beta = jax.nn.sigmoid(_proj(m32, wb.astype(jnp.float32)))[..., 0]
    pk = dpfp(k, nu)
    zk = jnp.einsum("nmp,np->nm", pk, z.astype(jnp.float32))
    vbar = jnp.einsum("nmp,npv->nmv", pk, A.astype(jnp.float32)) \
        / (zk + EPS)[..., None]
    gamma = 1.0 - zk / (jnp.sum(pk * pk, axis=-1) + EPS)
    A_new = A.astype(jnp.float32) + jnp.einsum("nm,nmv,nmp->npv",
                                               beta, v - vbar, pk)
    z_new = z.astype(jnp.float32) + jnp.einsum("nm,nmp->np", gamma, pk)
    return A_new.astype(A.dtype), z_new.astype(z.dtype)


def grouped_matmul_armt_update_ref(x, w, res, wk, wv, wb, A, z, bias=None, *,
                                   M: int, nu: int = 3):
    """Composition oracle for the fused GEMM + ARMT-update epilogue:
    y = res + x @ w (+ bias), then the delta-rule update fed from the last
    M rows of y per group (cast to the activation dtype first, matching
    both the fused kernel and the unfused two-launch path). x/res may be
    [G,M,K]/[G,M,N] or the un-flattened [G,B,T,K]/[G,B,T,N] grouped-block
    layout (B == 1; same fast-lowering rationale as grouped_matmul_ref)."""
    y32 = grouped_matmul_ref(x.astype(jnp.float32), w, bias) \
        + res.astype(jnp.float32)
    y = y32.astype(res.dtype)
    yf = y.reshape(y.shape[0], -1, y.shape[-1]) if x.ndim == 4 else y
    A2, z2 = armt_update_ref(yf[:, -M:, :], wk, wv, wb, A, z, nu=nu)
    return y, A2, z2


def mamba_scan_ref(x, dt, Bt, Ct, A_log, D, h0):
    """Token-sequential reference (fp32)."""
    A = -jnp.exp(A_log.astype(jnp.float32))

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        da = jnp.exp(dt_t[..., None] * A)
        h = da * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bis,bs->bi", h, C_t) + D * x_t
        return h, y

    xs = (x.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          Bt.swapaxes(0, 1).astype(jnp.float32),
          Ct.swapaxes(0, 1).astype(jnp.float32))
    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1), hT
