"""Jit'd op entry points, routed through the backend dispatch resolver
(kernels/dispatch.py, DESIGN.md §14): every call resolves one KernelConfig
— pallas-kernel vs. XLA-native, block sizes, interpret lowering — from the
per-call override, the autotune cache, or the per-backend heuristic table,
in that order. MXU-alignment padding stays here (the resolver is
shape-bucketed; padding is an op-local concern)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, ref
from repro.kernels.dispatch import KernelConfig
from repro.kernels.flash_attention import flash_attention
from repro.kernels.grouped_matmul import (grouped_matmul,
                                          grouped_matmul_armt_update)
from repro.kernels.armt_memory import armt_read, armt_update
from repro.kernels.decode_attention import decode_attention as \
    decode_attention_kernel
from repro.kernels.mamba_scan import mamba_scan
from repro.utils import round_up


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_axis(x, axis: int, to: int):
    pad = round_up(x.shape[axis], to) - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _resolve(op, shapes, dtype, use_kernel, interpret, config):
    if config is not None:
        return config
    return dispatch.resolve(op, shapes, dtype, use_kernel=use_kernel,
                            interpret=interpret)


def segment_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      use_kernel: bool | None = None,
                      interpret: bool | None = None,
                      config: KernelConfig | None = None):
    """Grouped attention with automatic 128-lane head-dim padding.
    q: [N,Hq,T,hd]; k/v: [N,Hkv,S,hd] — or the 5-D grouped-block layout
    q: [G,B,T,Hq,hd]; k/v: [G,B,S,Hkv,hd], which the XLA branch keeps
    un-flattened (the (g,b,h)-batched dot is what CPU XLA schedules
    fastest and what the vmap path lowers to; see DESIGN.md §14) and the
    pallas branch transposes at the boundary."""
    cfg = _resolve("flash_attention", (q.shape, k.shape), q.dtype,
                   use_kernel, interpret, config)
    if q.ndim == 5:
        if cfg.impl == "xla":
            return ref.flash_attention_grouped_ref(
                q, k, v, causal=causal, window=window,
                fast_softmax=cfg.fast_softmax,
                causal_blocks=cfg.causal_blocks)
        G, B, T, Hq, hd = q.shape
        flat = lambda a: a.reshape((G * B,) + a.shape[2:]).swapaxes(1, 2)
        out = segment_attention(flat(q), flat(k), flat(v), causal=causal,
                                window=window, config=cfg)
        return out.swapaxes(1, 2).reshape(G, B, T, Hq, hd)
    if cfg.impl == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    hd = q.shape[-1]
    hd_p = round_up(hd, 128)
    if hd_p != hd:
        # zero-pad head dim; scale is computed from the true hd inside ref,
        # so rescale q to keep softmax temperature identical
        scale_fix = (hd_p / hd) ** 0.5
        q = _pad_axis(q * scale_fix, -1, 128)
        k = _pad_axis(k, -1, 128)
        v = _pad_axis(v, -1, 128)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=cfg.interpret,
                          **cfg.blocks("block_q", "block_k"))
    return out[..., :hd]


def decode_attention(q, k, v, lengths, *, window: int = 0,
                     use_kernel: bool | None = None,
                     interpret: bool | None = None,
                     config: KernelConfig | None = None):
    """Single-token decode attention against the serve KV-cache layout.
    q: [B,Hq,hd]; k/v: [B,S,Hkv,hd]; lengths: [B]."""
    cfg = _resolve("decode_attention", (q.shape, k.shape), q.dtype,
                   use_kernel, interpret, config)
    if cfg.impl == "xla":
        return ref.decode_attention_ref(q, k, v, lengths, window=window)
    hd = q.shape[-1]
    hd_p = round_up(hd, 128)
    if hd_p != hd:
        scale_fix = (hd_p / hd) ** 0.5
        q = _pad_axis(q * scale_fix, -1, 128)
        k = _pad_axis(k, -1, 128)
        v = _pad_axis(v, -1, 128)
    out = decode_attention_kernel(q, k, v, lengths, window=window,
                                  interpret=cfg.interpret,
                                  **cfg.blocks("block_k"))
    return out[..., :hd]


def grouped_gemm(x, w, bias=None, *, activation: str | None = None,
                 use_kernel: bool | None = None,
                 interpret: bool | None = None,
                 config: KernelConfig | None = None):
    """Grouped GEMM with a fused bias + activation epilogue.
    x: [G,M,K] or the un-flattened grouped-block layout [G,B,T,K];
    w: [G,K,N]; bias: optional [G,N]; activation: None|silu|gelu.
    The XLA branch keeps the 4-D form (the fast CPU lowering — see
    grouped_matmul_ref); the pallas branch flattens rows at the kernel
    boundary."""
    cfg = _resolve("grouped_matmul", (x.shape, w.shape), x.dtype,
                   use_kernel, interpret, config)
    if cfg.impl == "xla":
        return ref.grouped_matmul_ref(x, w, bias, activation=activation)
    shape4 = x.shape if x.ndim == 4 else None
    if shape4 is not None:
        x = x.reshape(shape4[0], shape4[1] * shape4[2], shape4[3])
    out = grouped_matmul(x, w, bias, activation=activation,
                         interpret=cfg.interpret,
                         **cfg.blocks("block_m", "block_n", "block_k"))
    if shape4 is not None:
        out = out.reshape(shape4[:3] + (out.shape[-1],))
    return out


def grouped_gemm_armt_update(x, w, res, wk, wv, wb, A, z, bias=None, *,
                             M: int, nu: int = 3,
                             use_kernel: bool | None = None,
                             interpret: bool | None = None,
                             config: KernelConfig | None = None):
    """Grouped GEMM + residual with the ARMT delta-rule update fused into
    the epilogue (one launch instead of two per anti-diagonal cell).
    x/res: [G,R,K]/[G,R,N] or the un-flattened [G,B,T,K]/[G,B,T,N]
    grouped-block layout (B == 1). Falls back to the composition when the
    fused kernel's tiling constraints don't hold (mem rows straddling the
    last m-tile)."""
    cfg = _resolve("grouped_matmul_armt_update", (x.shape, w.shape, A.shape),
                   x.dtype, use_kernel, interpret, config)
    shape4 = x.shape if x.ndim == 4 else None
    if shape4 is not None and cfg.impl != "xla":
        x = x.reshape(shape4[0], shape4[1] * shape4[2], shape4[3])
        res = res.reshape(shape4[0], shape4[1] * shape4[2], res.shape[-1])
    R = x.shape[1] if x.ndim == 3 else x.shape[1] * x.shape[2]
    bm = min(cfg.block_m or 256, R)
    n_m = -(-R // bm)
    rows_last = R - (n_m - 1) * bm
    fusable = cfg.fuse_epilogue and rows_last >= M
    if cfg.impl == "xla":
        return ref.grouped_matmul_armt_update_ref(x, w, res, wk, wv, wb,
                                                  A, z, bias, M=M, nu=nu)
    if not fusable:
        y = res + grouped_matmul(x, w, bias, interpret=cfg.interpret,
                                 **cfg.blocks("block_m", "block_k"))
        A2, z2 = armt_update(y[:, -M:, :], wk, wv, wb, A, z, nu=nu,
                             interpret=cfg.interpret)
    else:
        y, A2, z2 = grouped_matmul_armt_update(
            x, w, res, wk, wv, wb, A, z, bias, M=M, nu=nu,
            interpret=cfg.interpret, **cfg.blocks("block_m", "block_k"))
    if shape4 is not None:
        y = y.reshape(shape4[:3] + (y.shape[-1],))
    return y, A2, z2


def assoc_read(x, wq, A, z, *, nu: int = 3, use_kernel: bool | None = None,
               interpret: bool | None = None,
               config: KernelConfig | None = None):
    cfg = _resolve("armt_read", (x.shape, A.shape), x.dtype,
                   use_kernel, interpret, config)
    if cfg.impl == "xla":
        return ref.armt_read_ref(x, wq, A, z, nu=nu)
    return armt_read(x, wq, A, z, nu=nu, interpret=cfg.interpret,
                     **cfg.blocks("block_t", "block_v"))


def assoc_update(m, wk, wv, wb, A, z, *, nu: int = 3,
                 use_kernel: bool | None = None,
                 interpret: bool | None = None,
                 config: KernelConfig | None = None):
    cfg = _resolve("armt_update", (m.shape, A.shape), m.dtype,
                   use_kernel, interpret, config)
    if cfg.impl == "xla":
        return ref.armt_update_ref(m, wk, wv, wb, A, z, nu=nu)
    return armt_update(m, wk, wv, wb, A, z, nu=nu, interpret=cfg.interpret,
                       **cfg.blocks("block_v"))


def selective_scan_fused(x, dt, Bt, Ct, A_log, D, h0, *,
                         use_kernel: bool | None = None,
                         interpret: bool | None = None,
                         config: KernelConfig | None = None):
    cfg = _resolve("mamba_scan", (x.shape, Bt.shape), x.dtype,
                   use_kernel, interpret, config)
    if cfg.impl == "xla":
        return ref.mamba_scan_ref(x, dt, Bt, Ct, A_log, D, h0)
    return mamba_scan(x, dt, Bt, Ct, A_log, D, h0, interpret=cfg.interpret,
                      **cfg.blocks("block_i"))
