"""Jit'd dispatch wrappers: pick the Pallas kernel on TPU, the jnp oracle on
CPU (or interpret=True for kernel validation), with MXU-alignment padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.grouped_matmul import grouped_matmul
from repro.kernels.armt_memory import armt_read, armt_update
from repro.kernels.mamba_scan import mamba_scan
from repro.utils import round_up


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_axis(x, axis: int, to: int):
    pad = round_up(x.shape[axis], to) - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def segment_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      use_kernel: bool | None = None,
                      interpret: bool | None = None):
    """Grouped attention with automatic 128-lane head-dim padding.
    q: [N,Hq,T,hd]; k/v: [N,Hkv,S,hd]."""
    use_kernel = on_tpu() if use_kernel is None else use_kernel
    if not use_kernel:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    hd = q.shape[-1]
    hd_p = round_up(hd, 128)
    if hd_p != hd:
        # zero-pad head dim; scale is computed from the true hd inside ref,
        # so rescale q to keep softmax temperature identical
        scale_fix = (hd_p / hd) ** 0.5
        q = _pad_axis(q * scale_fix, -1, 128)
        k = _pad_axis(k, -1, 128)
        v = _pad_axis(v, -1, 128)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=bool(interpret))
    return out[..., :hd]


def grouped_gemm(x, w, bias=None, *, activation: str | None = None,
                 use_kernel: bool | None = None,
                 interpret: bool | None = None):
    """Grouped GEMM with a fused bias + activation epilogue.
    x: [G,M,K]; w: [G,K,N]; bias: optional [G,N]; activation: None|silu|gelu."""
    use_kernel = on_tpu() if use_kernel is None else use_kernel
    if not use_kernel:
        return ref.grouped_matmul_ref(x, w, bias, activation=activation)
    return grouped_matmul(x, w, bias, activation=activation,
                          interpret=bool(interpret))


def assoc_read(x, wq, A, z, *, nu: int = 3, use_kernel: bool | None = None,
               interpret: bool | None = None):
    use_kernel = on_tpu() if use_kernel is None else use_kernel
    if not use_kernel:
        return ref.armt_read_ref(x, wq, A, z, nu=nu)
    return armt_read(x, wq, A, z, nu=nu, interpret=bool(interpret))


def assoc_update(m, wk, wv, wb, A, z, *, nu: int = 3,
                 use_kernel: bool | None = None,
                 interpret: bool | None = None):
    use_kernel = on_tpu() if use_kernel is None else use_kernel
    if not use_kernel:
        return ref.armt_update_ref(m, wk, wv, wb, A, z, nu=nu)
    return armt_update(m, wk, wv, wb, A, z, nu=nu, interpret=bool(interpret))


def selective_scan_fused(x, dt, Bt, Ct, A_log, D, h0, *,
                         use_kernel: bool | None = None,
                         interpret: bool | None = None):
    use_kernel = on_tpu() if use_kernel is None else use_kernel
    if not use_kernel:
        return ref.mamba_scan_ref(x, dt, Bt, Ct, A_log, D, h0)
    return mamba_scan(x, dt, Bt, Ct, A_log, D, h0, interpret=bool(interpret))
