"""Fault-tolerant training loop.

Features (DESIGN.md §4): auto-resume from the latest checkpoint, periodic
atomic keep-k checkpoints (async), preemption (SIGTERM/SIGINT) -> final
checkpoint, non-finite step skipping (inside train_step), step-time watchdog
for straggler detection, deterministic data resume from the step counter.
"""
from __future__ import annotations

import json
import signal
import time
from pathlib import Path
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ArchConfig
from repro.data import shard_batch
from repro.optim import OptimConfig
from repro.train.state import init_train_state, make_train_step


class Watchdog:
    """Flags steps exceeding `factor` x the median step time (straggler /
    hang detection; on a real cluster this triggers re-slicing)."""

    def __init__(self, factor: float = 3.0):
        self.times = []
        self.factor = factor

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) < 5:
            return False
        med = float(np.median(self.times[-50:]))
        return dt > self.factor * med


def train_loop(cfg: ArchConfig, ocfg: OptimConfig, data: Iterator[Dict],
               *, steps: int, ckpt_dir: Optional[str] = None,
               schedule: str = "auto", mode: str = "segmented",
               microbatches: int = 1, mesh=None, ckpt_every: int = 100,
               log_every: int = 10, seed: int = 0,
               log_fn: Callable[[Dict], None] = None,
               resume: bool = True) -> Dict:
    """Returns the final state dict and a history of metrics."""
    step_fn = jax.jit(make_train_step(cfg, ocfg, schedule=schedule, mode=mode,
                                      microbatches=microbatches),
                      donate_argnums=(0,))
    state = init_train_state(cfg, ocfg, jax.random.PRNGKey(seed))

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if mgr and resume and mgr.latest_step() is not None:
        state = mgr.restore(state)
        start_step = mgr.latest_step()
        print(f"[train] resumed from step {start_step}", flush=True)

    stop = {"flag": False}

    def _on_signal(sig, frame):
        stop["flag"] = True

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            old_handlers[sig] = signal.signal(sig, _on_signal)
        except ValueError:
            pass   # not the main thread

    wd = Watchdog()
    history = []
    log_path = Path(ckpt_dir) / "metrics.jsonl" if ckpt_dir else None
    it = iter(data)
    # fast-forward the deterministic stream on resume
    for _ in range(start_step):
        next(it)

    step = start_step
    try:
        for step in range(start_step, steps):
            batch = shard_batch(next(it), mesh)
            batch.pop("answer", None)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            metrics.update(step=step, step_time_s=round(dt, 4))
            if wd.observe(dt):
                metrics["straggler"] = True
                print(f"[watchdog] step {step} took {dt:.2f}s "
                      f"(>{wd.factor}x median)", flush=True)
            history.append(metrics)
            if log_path:
                with open(log_path, "a") as f:
                    f.write(json.dumps(metrics) + "\n")
            if log_fn and step % log_every == 0:
                log_fn(metrics)
            if mgr and (step + 1) % ckpt_every == 0:
                mgr.save(step + 1, state)
            if stop["flag"]:
                print(f"[train] preemption signal at step {step}; "
                      "checkpointing and exiting", flush=True)
                break
    finally:
        if mgr:
            mgr.save(step + 1, state, block=True)
        for sig, h in old_handlers.items():
            signal.signal(sig, h)
    return {"state": state, "history": history, "last_step": step + 1}
