"""TrainState + step builder: value_and_grad through the segmented executor,
microbatch gradient accumulation, non-finite step skipping (fault tolerance),
AdamW update."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import init_params, lm_loss
from repro.optim import OptimConfig, adamw_init, adamw_update


def init_train_state(cfg: ArchConfig, ocfg: OptimConfig, key) -> Dict:
    params = init_params(cfg, key)
    return {"params": params, "opt": adamw_init(params, ocfg)}


def train_state_specs(cfg: ArchConfig, ocfg: OptimConfig):
    return jax.eval_shape(
        lambda k: init_train_state(cfg, ocfg, k), jax.random.PRNGKey(0))


def make_train_step(cfg: ArchConfig, ocfg: OptimConfig, *,
                    schedule: str = "auto", mode: str = "segmented",
                    microbatches: int = 1, skip_nonfinite: bool = True):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def batch_loss(params, batch):
        return lm_loss(params, cfg, batch["tokens"], batch["labels"],
                       schedule=schedule, mode=mode,
                       loss_mask=batch.get("loss_mask"),
                       enc_frames=batch.get("enc_frames"))

    def grads_of(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(batch_loss)(params, batch)

        def mb(carry, mb_batch):
            loss_acc, g_acc = carry
            l, g = jax.value_and_grad(batch_loss)(params, mb_batch)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(a.dtype), g_acc, g)
            return (loss_acc + l, g_acc), None

        split = jax.tree_util.tree_map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, gsum), _ = jax.lax.scan(mb, (jnp.float32(0), zeros), split)
        g = jax.tree_util.tree_map(lambda x: x / microbatches, gsum)
        return loss / microbatches, g

    def train_step(state, batch):
        loss, grads = grads_of(state["params"], batch)
        new_params, new_opt, metrics = adamw_update(
            state["params"], grads, state["opt"], ocfg)
        if skip_nonfinite:
            ok = jnp.isfinite(loss) & jnp.isfinite(metrics["grad_norm"])
            sel = lambda n, o: jnp.where(ok, n, o)
            new_params = jax.tree_util.tree_map(sel, new_params, state["params"])
            new_opt = jax.tree_util.tree_map(sel, new_opt, state["opt"])
            metrics["skipped"] = (~ok).astype(jnp.float32)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
