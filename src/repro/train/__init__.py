from repro.train.state import (init_train_state, train_state_specs,
                               make_train_step)
