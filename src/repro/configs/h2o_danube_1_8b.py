"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention.

[dense] 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000
[arXiv:2401.16818; hf]
"""
from repro.configs import ArchConfig, ARMTConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_head=80,             # 2560 / 32
    d_ff=6912,
    vocab=32000,
    block_pattern=("attn",),
    norm="rmsnorm",
    act="silu",
    rope_theta=10000.0,
    sliding_window=4096,   # mistral-style SWA; >= ARMT segment => full attn per segment
    tie_embeddings=True,
    armt=ARMTConfig(segment_len=1024, num_mem_tokens=128, d_mem=64),
    source="arXiv:2401.16818; hf",
)
