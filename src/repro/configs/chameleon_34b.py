"""chameleon-34b — early-fusion VLM with VQ image tokens.

[vlm] 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536
[arXiv:2405.09818; unverified]

Early fusion: image VQ tokens share the text vocab, so the backbone is a dense
LM; the image tokenizer frontend is a STUB (input_specs() provides token ids).
Chameleon uses QK-norm for training stability — kept here.
"""
from repro.configs import ArchConfig, ARMTConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=22016,
    vocab=65536,
    block_pattern=("attn",),
    norm="rmsnorm",
    act="silu",
    qk_norm=True,
    rope_theta=10000.0,
    armt=ARMTConfig(segment_len=1024, num_mem_tokens=128, d_mem=64),
    source="arXiv:2405.09818; unverified",
)
