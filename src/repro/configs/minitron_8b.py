"""minitron-8b — pruned nemotron.

[dense] 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000
[arXiv:2407.14679; hf]
"""
from repro.configs import ArchConfig, ARMTConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab=256000,
    block_pattern=("attn",),
    norm="rmsnorm",
    act="silu",
    rope_theta=10000.0,
    armt=ARMTConfig(segment_len=1024, num_mem_tokens=128, d_mem=64),
    source="arXiv:2407.14679; hf",
)
