"""qwen2.5-32b — GQA with QKV bias.

[dense] 64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064
[hf:Qwen/Qwen2.5-0.5B family; hf]
"""
from repro.configs import ArchConfig, ARMTConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=27648,
    vocab=152064,
    block_pattern=("attn",),
    norm="rmsnorm",
    act="silu",
    qkv_bias=True,
    rope_theta=1000000.0,
    armt=ARMTConfig(segment_len=1024, num_mem_tokens=128, d_mem=64),
    source="hf:Qwen/Qwen2.5-32B; hf",
)
