"""chatglm3-6b — 2D (partial) RoPE, strongly-grouped GQA.

[dense] 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024
[arXiv:2406.12793; hf]
"""
from repro.configs import ArchConfig, ARMTConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_head=128,
    d_ff=13696,
    vocab=65024,
    block_pattern=("attn",),
    norm="rmsnorm",
    act="silu",
    qkv_bias=True,          # chatglm uses bias on QKV
    rope_theta=10000.0,
    rope_fraction=0.5,      # "2d" rope: rotary on half the head dims
    armt=ARMTConfig(segment_len=1024, num_mem_tokens=128, d_mem=64),
    source="arXiv:2406.12793; hf",
)
