"""The paper's own model family: Llama-3.2/3.1 + ARMT.

These are the models the paper benchmarks (160M / 1B / 3B / 8B) with ARMT
configuration (segment_size, memory_tokens) = (1024, 128), d_mem = 64.
"""
from repro.configs import ArchConfig, ARMTConfig

_ARMT = ARMTConfig(segment_len=1024, num_mem_tokens=128, d_mem=64)

CONFIGS = {
    "llama-160m-armt": ArchConfig(
        name="llama-160m-armt", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
        d_ff=3072, vocab=32000, block_pattern=("attn",),
        norm="rmsnorm", act="silu", rope_theta=10000.0,
        tie_embeddings=True, armt=_ARMT, source="paper Table 7"),
    "llama-1b-armt": ArchConfig(
        name="llama-1b-armt", family="dense",
        n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, d_head=64,
        d_ff=8192, vocab=128256, block_pattern=("attn",),
        norm="rmsnorm", act="silu", rope_theta=500000.0,
        tie_embeddings=True, armt=_ARMT, source="Llama-3.2-1B; paper Table 1"),
    "llama-3b-armt": ArchConfig(
        name="llama-3b-armt", family="dense",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, d_head=128,
        d_ff=8192, vocab=128256, block_pattern=("attn",),
        norm="rmsnorm", act="silu", rope_theta=500000.0,
        tie_embeddings=True, armt=_ARMT, source="Llama-3.2-3B; paper Table 5"),
    "llama-8b-armt": ArchConfig(
        name="llama-8b-armt", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
        d_ff=14336, vocab=128256, block_pattern=("attn",),
        norm="rmsnorm", act="silu", rope_theta=500000.0,
        armt=_ARMT, source="Llama-3.1-8B; paper Table 6"),
}
