"""falcon-mamba-7b — pure Mamba-1 architecture (attention-free).

[ssm] 64L d_model=4096 (attn-free) d_ff=0 vocab=65024, ssm_state=16
[arXiv:2410.05355; unverified]

A pure PRMT member: each layer's recurrent state is the SSM hidden state h
(plus the causal-conv tail), carried across segments; diagonal batching
parallelizes the 64-layer x n_segments grid exactly as for ARMT.
No associative memory is needed (the SSM state *is* the layer memory), so
armt=None; segmented execution uses ssm state carry with segment_len below.
"""
from repro.configs import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=65024,
    block_pattern=("mamba",),
    norm="rmsnorm",
    act="silu",
    use_rope=False,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    armt=None,             # SSM state is the layer-local memory
    source="arXiv:2410.05355; unverified",
)

# Segment length used when running falcon-mamba in segmented/diagonal mode
# (no memory tokens; the segment is purely a scheduling unit).
SEGMENT_LEN = 1024
