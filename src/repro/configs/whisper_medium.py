"""whisper-medium — encoder-decoder with conv frontend (stubbed).

[audio] 24L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=51865
[arXiv:2212.04356; unverified]

Backbone only per assignment: the conv frontend is a STUB — input_specs()
provides precomputed frame embeddings (B, 1500, d_model). Decoder layers are
ARMT-wrapped for long-context shapes; the encoder is non-recurrent (processes
all frames at once), so diagonal batching is N/A there by construction
(DESIGN.md §Arch-applicability).
"""
from repro.configs import ArchConfig, ARMTConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,            # decoder layers; encoder below
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,          # MHA
    d_head=64,
    d_ff=4096,
    vocab=51865,
    block_pattern=("dec",),  # decoder block: self-attn + cross-attn + mlp
    norm="layernorm",
    act="gelu",
    use_rope=False,          # whisper uses learned positional embeddings
    encoder=EncoderConfig(n_layers=24, n_frames=1500),
    armt=ARMTConfig(segment_len=1024, num_mem_tokens=128, d_mem=64),
    source="arXiv:2212.04356; unverified",
)
