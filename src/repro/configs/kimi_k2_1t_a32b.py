"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config).

[moe] 61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, MoE 384e top-8
[arXiv:2501.kimi2; unverified]

DeepSeek-V3-style: one leading dense layer, then 60 MoE layers with one shared
expert. The assigned d_ff=2048 is the per-expert (MoE intermediate) size; the
leading dense layer uses 9*2048=18432 so its FLOPs match an active MoE layer
(top-8 routed + 1 shared).
"""
from repro.configs import ArchConfig, ARMTConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=112,            # 7168 / 64
    d_ff=2048,             # per-expert intermediate (assignment value)
    vocab=163840,
    prelude=("attn",),     # first layer dense
    prelude_d_ff=18432,
    block_pattern=("attn_moe",),
    norm="rmsnorm",
    act="silu",
    rope_theta=50000.0,
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048, d_shared=2048,
                  capacity_factor=1.25),
    armt=ARMTConfig(segment_len=1024, num_mem_tokens=128, d_mem=64),
    source="arXiv:2501.kimi2; unverified",
)
