"""jamba-1.5-large-398b — Mamba+attention 1:7 interleave with MoE.

[hybrid] 72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2
[arXiv:2403.19887; hf]

Superblock of 8 layers: 1 attention + 7 mamba; MoE on every other layer
(4 of 8). 9 superblocks = 72 layers, 9 attention : 63 mamba = 1:7.
Mamba layers are PRMT members (layer-local h state), so diagonal batching
covers the whole heterogeneous stack via static slot-type partitioning.
"""
from repro.configs import ArchConfig, ARMTConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab=65536,
    block_pattern=("attn", "mamba_moe", "mamba", "mamba_moe",
                   "mamba", "mamba_moe", "mamba", "mamba_moe"),
    norm="rmsnorm",
    act="silu",
    use_rope=False,        # jamba attention layers use no positional encoding
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, d_shared=0,
                  capacity_factor=1.25),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    armt=ARMTConfig(segment_len=1024, num_mem_tokens=128, d_mem=64),
    source="arXiv:2403.19887; hf",
)
