"""qwen2-moe-a2.7b — 60 routed experts top-4 + shared expert.

[moe] 24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936, MoE 60e top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

The "4 shared" experts are modeled as one shared FFN of 4*1408 = 5632
(matching hf shared_expert_intermediate_size).
"""
from repro.configs import ArchConfig, ARMTConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,         # MHA
    d_head=128,
    d_ff=1408,             # per-expert intermediate (assignment value)
    vocab=151936,
    block_pattern=("attn_moe",),
    norm="rmsnorm",
    act="silu",
    qkv_bias=True,
    rope_theta=1000000.0,
    moe=MoEConfig(n_experts=60, top_k=4, d_expert=1408, d_shared=5632,
                  capacity_factor=1.25),
    armt=ARMTConfig(segment_len=1024, num_mem_tokens=128, d_mem=64),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
)
