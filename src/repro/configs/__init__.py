"""Architecture configs and input-shape registry.

Every assigned architecture has its own module ``<id>.py`` exporting ``CONFIG``.
``get_config(arch_id)`` resolves ids like ``"qwen2.5-32b"``; ``get_smoke_config``
returns a reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Config dataclasses
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int              # per-expert FFN hidden size
    d_shared: int = 0          # shared-expert FFN hidden size (0 = no shared expert)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # 'global': one argsort over all tokens (exact capacity, but the sort
    # gathers across data shards under SPMD); 'per_row': dispatch per batch
    # row — fully local under batch sharding (GSPMD-MoE 'groups' semantics)
    dispatch: str = "global"


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0           # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class ARMTConfig:
    """Associative Recurrent Memory Transformer (paper eqs. 3-6)."""
    segment_len: int = 1024    # tokens per segment (paper's main config)
    num_mem_tokens: int = 128  # memory tokens appended per segment
    d_mem: int = 64            # key dim before DPFP (phi maps to 2*nu*d_mem)
    d_val: int = 0             # value dim of A; 0 -> d_model
    nu: int = 3                # DPFP order (DPFP-3 in the paper)


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec archs (whisper). Frontend is a stub: the
    input spec provides precomputed frame embeddings (B, n_frames, d_model)."""
    n_layers: int
    n_frames: int = 1500


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int              # total decoder/backbone layers
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                  # dense FFN hidden (0 for attn-free archs)
    vocab: int
    d_head: int = 0            # 0 -> d_model // n_heads
    # Layer-stack structure: n_prelude 'prelude' layers of type prelude_type,
    # then block_pattern repeated n_superblocks times.
    block_pattern: Tuple[str, ...] = ("attn",)
    prelude: Tuple[str, ...] = ()       # e.g. kimi's single leading dense layer
    norm: str = "rmsnorm"      # rmsnorm | layernorm
    act: str = "silu"          # silu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # chatglm applies rotary to half the head dims
    use_rope: bool = True       # whisper decoder uses learned positions instead
    sliding_window: int = 0     # 0 = full causal attention
    tie_embeddings: bool = False
    prelude_d_ff: int = 0       # dense FFN size for prelude layers (kimi)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    armt: Optional[ARMTConfig] = None   # None -> pure full attention
    encoder: Optional[EncoderConfig] = None
    max_position: int = 131072
    dtype: str = "bfloat16"
    remat: str = "full"        # none | dots | full
    attn_impl: str = "dense"   # dense | chunked (flash-style online softmax)
    # Diagonal-executor grouped-block implementation: 'vmap' applies the
    # scalar block per slot via jax.vmap (exactness oracle, autodiff-safe);
    # 'fused' launches the grouped Pallas kernels over the whole group
    # (models/grouped_blocks.py; forward/inference fast path).
    grouped_impl: str = "vmap"  # vmap | fused
    # Kernel lowering for the fused path's op calls (kernels/dispatch.py):
    # 'auto' lets the resolver pick per backend (autotune cache, then the
    # heuristic table — XLA-native on CPU, Pallas on TPU/GPU); 'xla' and
    # 'pallas' force the implementation; 'pallas_interpret' forces the
    # kernel bodies under interpret-mode lowering (CPU validation).
    kernel_backend: str = "auto"  # auto | xla | pallas | pallas_interpret
    # Blockwise segment cells (DESIGN.md §15): query-block size for the
    # intra-cell FFN so per-cell activation peaks are O(cell_block·d_ff)
    # instead of O(T·d_ff) (BPT-style; attention already blocks via
    # attn_impl='chunked' / the dispatch resolver's causal_blocks). 0 (the
    # default) keeps the unblocked path — blocked accumulation can differ
    # in ulps, so the bit-exactness oracles stay on 0.
    cell_block: int = 0
    source: str = ""           # provenance note

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def n_superblocks(self) -> int:
        body = self.n_layers - len(self.prelude)
        assert body % len(self.block_pattern) == 0, (
            f"{self.name}: {body} layers do not tile by pattern {self.block_pattern}")
        return body // len(self.block_pattern)

    @property
    def layer_types(self) -> Tuple[str, ...]:
        """Flat per-layer type list (prelude + pattern * n_superblocks)."""
        return tuple(self.prelude) + tuple(self.block_pattern) * self.n_superblocks

    @property
    def is_recurrent(self) -> bool:
        """True if every layer carries layer-local recurrent state (PRMT family)."""
        return self.armt is not None or all(
            t.startswith("mamba") for t in self.layer_types)

    def validate(self) -> None:
        assert self.d_model > 0 and self.n_layers > 0 and self.vocab > 0
        assert self.grouped_impl in ("vmap", "fused"), self.grouped_impl
        assert self.kernel_backend in (
            "auto", "xla", "pallas", "pallas_interpret"), self.kernel_backend
        assert self.cell_block >= 0, self.cell_block
        if any(t.startswith("attn") or t.startswith("dec") or t.startswith("enc")
               for t in self.layer_types):
            assert self.n_heads > 0 and self.n_kv_heads > 0
            assert self.n_heads % self.n_kv_heads == 0
        if any(t.endswith("moe") for t in self.layer_types):
            assert self.moe is not None
        if any(t.startswith("mamba") for t in self.layer_types):
            assert self.ssm is not None
        _ = self.n_superblocks  # asserts pattern tiling


# ---------------------------------------------------------------------------
# Input shapes (assigned; identical set for every LM arch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ARCH_MODULES = {
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen2.5-32b": "qwen2_5_32b",
    "minitron-8b": "minitron_8b",
    "chatglm3-6b": "chatglm3_6b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-medium": "whisper_medium",
    "chameleon-34b": "chameleon_34b",
    # The paper's own model family (Llama-3 + ARMT)
    "llama-160m-armt": "llama_armt",
    "llama-1b-armt": "llama_armt",
    "llama-3b-armt": "llama_armt",
    "llama-8b-armt": "llama_armt",
}

ASSIGNED_ARCHS = [
    "h2o-danube-1.8b", "qwen2.5-32b", "minitron-8b", "chatglm3-6b",
    "kimi-k2-1t-a32b", "qwen2-moe-a2.7b", "jamba-1.5-large-398b",
    "falcon-mamba-7b", "whisper-medium", "chameleon-34b",
]


def get_config(arch_id: str) -> ArchConfig:
    mod_name = _ARCH_MODULES.get(arch_id)
    if mod_name is None:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    if hasattr(mod, "CONFIGS"):
        cfg = mod.CONFIGS[arch_id]
    else:
        cfg = mod.CONFIG
    cfg.validate()
    return cfg


def list_archs():
    return list(_ARCH_MODULES)


# ---------------------------------------------------------------------------
# Smoke-test reduction: same family, tiny dims, runnable on 1 CPU core.
# ---------------------------------------------------------------------------

def get_smoke_config(arch_id: str, *, seq_len: int = 64) -> ArchConfig:
    cfg = get_config(arch_id)
    n_pattern = len(cfg.block_pattern)
    # two superblocks (cross-superblock recurrence coverage) unless the
    # pattern itself is long (jamba: 8 heterogeneous layers) — one repeat of
    # a long pattern already exercises every block type, and doubling it
    # used to make that single arch dominate the tier-1 wall-clock
    n_sb = 1 if n_pattern >= 4 else 2
    n_layers = len(cfg.prelude) + n_sb * n_pattern
    armt = None
    if cfg.armt is not None:
        armt = replace(cfg.armt, segment_len=max(8, seq_len // 4),
                       num_mem_tokens=4, d_mem=8, d_val=0)
    moe = None
    if cfg.moe is not None:
        moe = replace(cfg.moe, n_experts=4, top_k=min(2, cfg.moe.top_k),
                      d_expert=32, d_shared=(32 if cfg.moe.d_shared else 0))
    ssm = None
    if cfg.ssm is not None:
        ssm = replace(cfg.ssm, d_state=4, d_conv=4, expand=2)
    enc = None
    if cfg.encoder is not None:
        enc = replace(cfg.encoder, n_layers=2, n_frames=16)
    return replace(
        cfg,
        n_layers=n_layers,
        d_model=32,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_head=8,
        d_ff=(64 if cfg.d_ff else 0),
        prelude_d_ff=(64 if cfg.prelude_d_ff else 0),
        vocab=256,
        armt=armt, moe=moe, ssm=ssm, encoder=enc,
        max_position=max(2048, seq_len),
        dtype="float32",
        remat="none",
    )
