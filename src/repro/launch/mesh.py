"""Production mesh definitions (TPU v5e: 256 chips/pod, 16x16).

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever local devices exist (tests / CPU)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link (~4 links/chip on the 2D torus)
