"""Production mesh definitions (TPU v5e: 256 chips/pod, 16x16).

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever local devices exist (tests / CPU)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


_MESH_AXES = ("pod", "data", "model", "stage")


def parse_mesh(spec: str, devices=None):
    """Build a Mesh from a ``--mesh`` flag value: comma-separated
    ``axis[=size]`` entries, axes from {pod, data, model, stage} in that
    order. At most one axis may omit its size — it absorbs the devices the
    explicit axes leave over. The sizes must use *every* available device
    (subsetting silently would falsify the device_count provenance recorded
    by benchmarks; pass ``devices=`` to use fewer). Examples (8 devices):

        --mesh data=2,model=4        -> Mesh (2, 4) ('data', 'model')
        --mesh data,model=4          -> data gets 8 // 4 = 2
        --mesh data=2,stage=2        -> serving with pipeline slot sharding

    The serving stack (`ServeEngine(mesh=...)`, DESIGN.md §10) derives all
    placement from the axis *names*; sizes only pick how the device grid is
    carved up."""
    import numpy as np
    from jax.sharding import Mesh

    devices = list(jax.devices()) if devices is None else list(devices)
    axes, sizes, open_axis = [], [], None
    for entry in spec.split(","):
        entry = entry.strip()
        if not entry:
            continue
        name, _, size = entry.partition("=")
        if name not in _MESH_AXES:
            raise ValueError(
                f"unknown mesh axis {name!r} in --mesh {spec!r}; "
                f"choose from {_MESH_AXES}")
        if name in axes:
            raise ValueError(f"duplicate mesh axis {name!r} in {spec!r}")
        if size:
            if int(size) < 1:
                raise ValueError(
                    f"--mesh {spec!r}: axis size must be >= 1, got "
                    f"{name}={size}")
            axes.append(name), sizes.append(int(size))
        else:
            if open_axis is not None:
                raise ValueError(
                    f"--mesh {spec!r}: at most one axis may omit its size")
            axes.append(name), sizes.append(0)
            open_axis = len(axes) - 1
    if not axes:
        raise ValueError(f"empty --mesh spec {spec!r}")
    axes = tuple(axes)
    known = int(np.prod([s for s in sizes if s]))
    if open_axis is not None:
        if len(devices) % known:
            raise ValueError(
                f"--mesh {spec!r}: {known} explicit devices do not divide "
                f"the {len(devices)} available")
        sizes[open_axis] = len(devices) // known
    total = int(np.prod(sizes))
    if total != len(devices):
        # never silently serve on a subset: device_count is recorded as
        # provenance in bench metadata, so a mesh that quietly dropped
        # devices would misstate every comparison keyed on it. To use fewer
        # devices, pass devices= explicitly (or restrict visible devices).
        raise ValueError(
            f"--mesh {spec!r} carves {total} device(s) but {len(devices)} "
            f"are available — add an open axis (e.g. 'data,{spec}') or "
            "match the sizes to the device count")
    grid = np.asarray(devices).reshape(tuple(sizes))
    return Mesh(grid, axes)


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link (~4 links/chip on the 2D torus)
