"""Per-(arch x shape) cell construction for the dry-run: the step function to
lower, ShapeDtypeStruct input stand-ins (no allocation), and shardings.

Cell semantics (DESIGN.md §5):
  train_4k     train_step: fwd(segmented, schedule) + CE + grads + AdamW
  prefill_32k  prefill: segmented forward -> (last logits, serve state)
  decode_32k   serve_step vs a full KV cache of seq_len ('cache' mode) for
               attention archs; SSM-state decode for attention-free archs
  long_500k    serve_step in 'armt'/SSM mode — state is O(1) in context,
               which is the paper's Fig. 1 memory claim
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct as SDS
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ArchConfig, ShapeSpec, get_config
from repro.models import decode_state_init, decode_step, forward_hidden, last_logits
from repro.models.model import param_specs as model_param_specs
from repro.optim import OptimConfig
from repro.parallel import sharding as shd
from repro.train import make_train_step, train_state_specs


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: Any                   # function to jit
    args: Tuple               # ShapeDtypeStruct pytrees
    in_shardings: Tuple
    out_shardings: Any
    meta: Dict
    donate: Tuple[int, ...] = ()


def _token_inputs(cfg: ArchConfig, shape: ShapeSpec, mesh, *, train: bool):
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": SDS((B, S), jnp.int32)}
    if train:
        batch["labels"] = SDS((B, S), jnp.int32)
    if cfg.encoder is not None:
        batch["enc_frames"] = SDS(
            (B, cfg.encoder.n_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch, shd.batch_specs(mesh, batch)


def resolve_schedule(cfg: ArchConfig, shape: ShapeSpec,
                     schedule: Optional[str]) -> str:
    if schedule:
        return schedule
    seg = cfg.armt.segment_len if cfg.armt else 1024
    n_seg = shape.seq_len // seg
    return "diagonal" if n_seg >= cfg.n_layers else "sequential"


def _needs_fsdp(cfg: ArchConfig, mesh) -> bool:
    """Params (bf16) per device exceed half the 16 GiB HBM under TP-only ->
    shard them over the DP axes too (ZeRO-3/FSDP)."""
    from repro.roofline.model_math import param_counts
    total, _ = param_counts(cfg)
    per_dev = total * 2 / shd.tp_size(mesh)
    return per_dev > 8e9


def _default_microbatches(cfg: ArchConfig, mesh) -> int:
    """Keep per-device microbatch activations modest for wide/deep archs."""
    if cfg.d_model >= 7000:
        return 8
    if cfg.d_model >= 4096 or cfg.n_layers >= 48:
        return 4
    return 1


def build_cell(arch: str, shape_name: str, mesh, *,
               schedule: Optional[str] = None,
               serve_mode: Optional[str] = None,
               microbatches: Optional[int] = None,
               zero1: bool = True,
               fsdp: Optional[bool] = None,
               moment_dtype: Optional[str] = None,
               cfg_override: Optional[ArchConfig] = None) -> Cell:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    dtype = jnp.dtype(cfg.dtype)
    fsdp = _needs_fsdp(cfg, mesh) if fsdp is None else fsdp

    if shape.kind == "train":
        sched = resolve_schedule(cfg, shape, schedule)
        big = cfg.name.startswith(("kimi", "jamba"))
        mdt = moment_dtype or ("bfloat16" if big else "float32")
        ocfg = OptimConfig(moment_dtype=mdt, factored_v=big)
        mb = (_default_microbatches(cfg, mesh)
              if microbatches is None else microbatches)
        step = make_train_step(cfg, ocfg, schedule=sched, microbatches=mb)
        state_shape = train_state_specs(cfg, ocfg)
        pspecs = shd.param_specs(state_shape["params"], mesh, fsdp=fsdp)
        ospecs = shd.opt_state_specs(state_shape["opt"],
                                     state_shape["params"], mesh, zero1=zero1)
        state_shardings = {"params": pspecs, "opt": ospecs}
        batch, bspecs = _token_inputs(cfg, shape, mesh, train=True)
        rep = shd.replicated(mesh)
        out_shardings = (state_shardings,
                         {"loss": rep, "lr": rep, "grad_norm": rep,
                          "skipped": rep})
        return Cell(arch, shape_name, step, (state_shape, batch),
                    (state_shardings, bspecs), out_shardings,
                    {"kind": "train", "schedule": sched,
                     "microbatches": mb, "zero1": zero1, "fsdp": fsdp,
                     "moment_dtype": mdt, "factored_v": big}, donate=(0,))

    if shape.kind == "prefill":
        sched = resolve_schedule(cfg, shape, schedule)

        def prefill(params, batch):
            hidden, fin = forward_hidden(
                params, cfg, batch["tokens"], schedule=sched,
                enc_frames=batch.get("enc_frames"))
            return last_logits(params, cfg, hidden), fin

        pshape = model_param_specs(cfg)
        pspecs = shd.param_specs(pshape, mesh, fsdp=fsdp)
        batch, bspecs = _token_inputs(cfg, shape, mesh, train=False)
        return Cell(arch, shape_name, prefill, (pshape, batch),
                    (pspecs, bspecs), None,
                    {"kind": "prefill", "schedule": sched, "fsdp": fsdp})

    # decode
    mode = serve_mode or ("cache" if shape_name == "decode_32k" else "armt")
    if not any(t.startswith("attn") or t == "dec" for t in cfg.layer_types):
        mode = "armt"   # attention-free: state decode either way

    def serve(params, dstate, tokens):
        return decode_step(params, cfg, dstate, tokens, serve_mode=mode)

    pshape = model_param_specs(cfg)
    pspecs = shd.param_specs(pshape, mesh, fsdp=fsdp)
    B = shape.global_batch
    dshape = jax.eval_shape(
        lambda: decode_state_init(cfg, B, serve_mode=mode,
                                  max_len=shape.seq_len, dtype=dtype))
    dspecs = shd.decode_state_specs(dshape, mesh, B)
    toks = SDS((B,), jnp.int32)
    tspec = NamedSharding(mesh, P(shd.batch_axes(mesh, B)))
    return Cell(arch, shape_name, serve, (pshape, dshape, toks),
                (pspecs, dspecs, tspec), (None, dspecs),
                {"kind": "decode", "serve_mode": mode, "fsdp": fsdp,
                 "cache_len": shape.seq_len}, donate=(1,))
