"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

On a real TPU fleet this runs once per host under the JAX distributed
runtime (jax.distributed.initialize from TPU env vars); on CPU it drives the
reduced config end-to-end with the same code path: data -> sharded batches ->
fault-tolerant loop -> checkpoints.
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--schedule", default="auto",
                    choices=["auto", "diagonal", "sequential"])
    ap.add_argument("--task", default="needle", choices=["needle", "lm"])
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distributed", action="store_true",
                    help="initialize the JAX distributed runtime (multi-host)")
    args = ap.parse_args()

    if args.distributed:
        import jax
        jax.distributed.initialize()

    from repro.configs import get_config, get_smoke_config
    from repro.data import lm_stream, needle_qa
    from repro.optim import OptimConfig
    from repro.train.loop import train_loop

    cfg = (get_smoke_config(args.arch, seq_len=args.seq_len)
           if args.smoke else get_config(args.arch))
    ocfg = OptimConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(10, args.steps // 20))
    gen = needle_qa if args.task == "needle" else lm_stream
    data = gen(cfg.vocab, args.batch, args.seq_len, seed=args.seed)

    def log(m):
        print(f"step {m['step']:5d} loss {m['loss']:.4f} "
              f"gnorm {m['grad_norm']:.2f} lr {m['lr']:.2e} "
              f"dt {m['step_time_s']:.2f}s", flush=True)

    out = train_loop(cfg, ocfg, data, steps=args.steps,
                     ckpt_dir=args.ckpt_dir, schedule=args.schedule,
                     microbatches=args.microbatches, log_fn=log, log_every=10,
                     seed=args.seed)
    print(f"done at step {out['last_step']}; "
          f"final loss {out['history'][-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
