"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and record memory/cost/roofline artifacts.

MUST set the host-device override before any other import touches jax.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("PREPEND_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path # noqa: E402

import jax               # noqa: E402

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config   # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.launch.specs import build_cell                      # noqa: E402
from repro.roofline import HloAnalyzer, model_flops, roofline_terms  # noqa: E402


def measure(mesh, cell, mf: float, record: dict, *, save_dir: Path = None,
            save_hlo: bool = False, tag: str = "") -> dict:
    """Lower + compile a Cell on `mesh`; fill `record` with cost/memory/
    roofline artifacts."""
    t0 = time.time()
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        try:
            ma = compiled.memory_analysis()
            mem = {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                "generated_code_bytes":
                    getattr(ma, "generated_code_size_in_bytes", None),
            }
        except Exception as e:   # CPU backend may not support it
            mem = {"error": str(e)}
        hlo = compiled.as_text()
        n_dev = mesh.size
        analyzer = HloAnalyzer(hlo, n_dev)
        coll = analyzer.collectives()
        roof = roofline_terms(analyzer, n_dev, mf)
        record.update({
            "ok": True,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "cost": {k: cost.get(k) for k in
                     ("flops", "bytes accessed", "transcendentals")},
            "memory": mem,
            "collectives": {"wire_bytes": coll.total_wire_bytes,
                            "per_op": coll.per_op, "count": coll.count},
            "roofline": roof.to_dict(),
            "hlo_bytes": len(hlo),
        })
        if save_hlo and save_dir:
            (save_dir / f"{record['arch']}__{record['shape']}__"
             f"{record['mesh']}{tag}.hlo.txt").write_text(hlo)
    return record


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             schedule=None, serve_mode=None, microbatches=None,
             save_dir: Path = None, tag: str = "", verbose: bool = True,
             save_hlo: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    record = {"arch": arch, "shape": shape_name,
              "mesh": "x".join(str(s) for s in mesh.devices.shape),
              "n_devices": n_dev, "tag": tag, "ok": False}
    try:
        with mesh:
            cell = build_cell(arch, shape_name, mesh, schedule=schedule,
                              serve_mode=serve_mode, microbatches=microbatches)
        record["meta"] = cell.meta
        mf = model_flops(get_config(arch), SHAPES[shape_name])
        measure(mesh, cell, mf, record, save_dir=save_dir,
                save_hlo=save_hlo, tag=tag)
    except Exception as e:
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["total_s"] = round(time.time() - t0, 2)
    if save_dir:
        save_dir.mkdir(parents=True, exist_ok=True)
        name = f"{arch}__{shape_name}__{record['mesh']}{tag}.json"
        (save_dir / name).write_text(json.dumps(record, indent=1, default=str))
    if verbose:
        status = "OK " if record["ok"] else "FAIL"
        extra = ""
        if record["ok"]:
            r = record["roofline"]
            extra = (f" compile={record['compile_s']:.0f}s"
                     f" comp={r['compute_s']:.3e}s mem={r['memory_s']:.3e}s"
                     f" coll={r['collective_s']:.3e}s dom={r['dominant']}"
                     f" useful={r['useful_ratio']:.2f}")
        else:
            extra = " " + record.get("error", "")[:160]
        print(f"[{status}] {arch:22s} {shape_name:12s} mesh={record['mesh']}"
              f"{extra}", flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, comma list, or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--schedule", default=None)
    ap.add_argument("--serve-mode", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out = Path(args.out)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, multi_pod=mp,
                               schedule=args.schedule,
                               serve_mode=args.serve_mode,
                               microbatches=args.microbatches,
                               save_dir=out, tag=args.tag,
                               save_hlo=args.save_hlo)
                n_fail += 0 if rec["ok"] else 1
    print(f"dry-run complete; failures: {n_fail}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
