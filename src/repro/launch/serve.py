"""Serving driver: ``python -m repro.launch.serve --arch <id> --smoke``.

Demonstrates the paper's deployment story: one long-context request at a
time, prefilled with diagonal batching, decoded against constant-size ARMT
state.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--serve-mode", default="armt", choices=["armt", "cache"])
    ap.add_argument("--schedule", default="diagonal",
                    choices=["diagonal", "sequential"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, get_smoke_config
    from repro.models import init_params
    from repro.serve import ServeEngine

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                 (args.batch, args.prompt_len), 8, cfg.vocab)
    eng = ServeEngine(params, cfg, serve_mode=args.serve_mode,
                      schedule=args.schedule,
                      max_len=args.prompt_len + args.max_new)
    t0 = time.perf_counter()
    res = eng.generate(prompts, args.max_new)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} mode={args.serve_mode} schedule={res.schedule} "
          f"prefill_segments={res.prefill_segments}")
    print(f"generated {res.tokens.shape} tokens in {dt:.2f}s")
    print("first row:", res.tokens[0].tolist())


if __name__ == "__main__":
    main()
