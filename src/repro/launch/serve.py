"""Serving driver: ``python -m repro.launch.serve --arch <id> --smoke``.

Two modes:
  * single (default): one fixed-shape batch, prefilled with diagonal
    batching, decoded on-device against constant-size ARMT state.
  * ``--continuous``: a stream of requests with heterogeneous prompt
    lengths through the continuous-batching scheduler
    (serve/scheduler.py) — tokens stream back per request as they are
    produced.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--serve-mode", default="armt", choices=["armt", "cache"])
    ap.add_argument("--schedule", default="diagonal",
                    choices=["diagonal", "sequential"])
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 samples on device")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over --requests requests")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import get_config, get_smoke_config
    from repro.models import init_params
    from repro.serve import ServeEngine, Request

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if args.continuous and (args.temperature > 0 or args.top_k > 0):
        ap.error("--continuous streams greedy tokens; --temperature/--top-k "
                 "apply to single-batch mode only")
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    seg = cfg.armt.segment_len if cfg.armt else 64
    # headroom for the longer of the two continuous prompt buckets
    eng = ServeEngine(params, cfg, serve_mode=args.serve_mode,
                      schedule=args.schedule,
                      max_len=args.prompt_len + seg // 2 + args.max_new)

    if args.continuous:
        rng = np.random.default_rng(args.seed + 1)
        # two prompt-length buckets: heterogeneous segment phases without a
        # fresh prefill compile per request (cf. benchmarks/bench_serve.py)
        lens = [args.prompt_len if i % 2 == 0
                else max(1, args.prompt_len + seg // 2)
                for i in range(args.requests)]
        reqs = [Request(req_id=f"r{i}",
                        prompt=rng.integers(8, cfg.vocab, (L,)).astype("int32"),
                        max_new=args.max_new)
                for i, L in enumerate(lens)]
        t0 = time.perf_counter()
        n_tok = 0
        firsts = {}
        outs = {r.req_id: [] for r in reqs}
        for ev in eng.serve(reqs, n_slots=args.slots, chunk=args.chunk):
            n_tok += 1
            outs[ev.req_id].append(ev.token)
            firsts.setdefault(ev.req_id, time.perf_counter() - t0)
            if ev.done:
                print(f"{ev.req_id}: done ({ev.index + 1} tokens, "
                      f"ttft={firsts[ev.req_id]:.2f}s) "
                      f"first 8: {outs[ev.req_id][:8]}")
        dt = time.perf_counter() - t0
        print(f"arch={cfg.name} mode={args.serve_mode} slots={args.slots} "
              f"requests={args.requests}")
        print(f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s)")
        return

    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                 (args.batch, args.prompt_len), 8, cfg.vocab)
    t0 = time.perf_counter()
    res = eng.generate(prompts, args.max_new, temperature=args.temperature,
                       top_k=args.top_k, seed=args.seed)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} mode={args.serve_mode} schedule={res.schedule} "
          f"prefill_segments={res.prefill_segments}")
    print(f"generated {res.tokens.shape} tokens in {dt:.2f}s")
    print("first row:", res.tokens[0].tolist())


if __name__ == "__main__":
    main()
