"""Serving driver: ``python -m repro.launch.serve --arch <id> --smoke``.

Two modes:
  * single (default): one fixed-shape batch, prefilled with diagonal
    batching, decoded on-device against constant-size ARMT state. With
    ``--session-store`` it runs a two-turn session demo (turn 2 resumes
    from the stored state instead of re-prefilling turn 1).
  * ``--continuous``: a stream of requests with heterogeneous prompt
    lengths through the continuous-batching scheduler
    (serve/scheduler.py) — tokens stream back per request as they are
    produced. Scheduler rejections (queue-full, invalid request, evicted
    session) arrive as structured ``RequestError`` events on the same
    stream and are printed, never raised out of the iterator mid-serve.
    With ``--prefix-cache`` the requests share a system prompt and
    admission transplants the cached boundary snapshot (state store,
    DESIGN.md §9).

Both modes accept ``--mesh data=2,model=4[,stage=..]`` (launch/mesh.py
``parse_mesh``) for mesh-native serving (DESIGN.md §10): params shard over
'model' (and the stacked pattern over 'stage'), decode slots over 'data',
and the whole serve stack stays single jitted graphs with GSPMD inserting
the collectives. Sharded serving is token-identical to single-device
(tests/test_serve_sharded.py).

Observability (DESIGN.md §13): ``--trace-out trace.json`` records the
chunk-granular span timeline (Chrome-trace JSON for
https://ui.perfetto.dev; schema-validated on export, gated in CI via
``python -m repro.serve.telemetry``), ``--metrics`` / ``--metrics-out``
dump the metrics-registry snapshot (admissions, flushes, queue waits,
pool occupancy, jit-compile counts, XLA backend compiles), and
``--profile-dir`` captures a ``jax.profiler`` trace whose
``named_scope``/``TraceAnnotation`` host spans line up with the
recorder's timeline.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--serve-mode", default="armt", choices=["armt", "cache"])
    ap.add_argument("--schedule", default="diagonal",
                    choices=["diagonal", "sequential"])
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 samples on device")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over --requests requests")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--max-queue", type=int, default=None,
                    help="push model: drain the source into a backlog of at "
                         "most this many requests and reject (structured "
                         "queue_full event) beyond it; default is the pull "
                         "model (requests pulled lazily between chunks)")
    ap.add_argument("--prefill-groups-per-chunk", type=int, default=4,
                    help="interleaved admission (DESIGN.md §11): advance "
                         "the admitting request's diagonal prefill this "
                         "many groups per decode chunk instead of blocking "
                         "every slot for the whole prompt; 0 = legacy "
                         "blocking admission")
    ap.add_argument("--fused-admission", action="store_true",
                    help="run the admissions' diagonal groups inside the "
                         "same jitted launch as the decode chunk (one "
                         "dispatch per chunk interval)")
    ap.add_argument("--max-concurrent-admissions", type=int, default=None,
                    help="pooled concurrent admissions (DESIGN.md §12): up "
                         "to this many interleaved admissions in flight at "
                         "once, same-signature prefill carries batched into "
                         "one pooled launch per round; default None bounds "
                         "the pool only by free slots, 1 restores the "
                         "single-admission behavior")
    ap.add_argument("--admission-fairness", default="round_robin",
                    choices=["round_robin", "oldest_first"],
                    help="group-budget policy across in-flight admissions: "
                         "round_robin advances every carry k groups per "
                         "round; oldest_first is head-of-line")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="segment-granular prefix cache: requests share a "
                         "system prompt; admission transplants the cached "
                         "boundary state instead of re-prefilling it")
    ap.add_argument("--prefix-cache-mb", type=float, default=64.0,
                    help="prefix-cache LRU byte budget")
    ap.add_argument("--session-store", action="store_true",
                    help="multi-turn session demo: turn 2 resumes from the "
                         "stored end-of-turn-1 state")
    ap.add_argument("--session-mb", type=float, default=128.0)
    ap.add_argument("--store-dir", default=None,
                    help="disk-spill directory for evicted store entries "
                         "(checkpoint-manager named blobs)")
    ap.add_argument("--mesh", default=None, metavar="AXES",
                    help="mesh-native serving (DESIGN.md §10): comma list of "
                         "axis[=size] from {pod,data,model,stage}, e.g. "
                         "'data=2,model=4' or 'data,model=2' (one size may "
                         "be omitted). Params shard over 'model'/'stage', "
                         "decode slots over 'data'; GSPMD does the "
                         "collectives")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="observability (DESIGN.md §13): write the serve "
                         "run's chunk-granular trace timeline as Chrome-"
                         "trace/Perfetto JSON (load in ui.perfetto.dev)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the engine's metrics snapshot (compile "
                         "counts, store stats, serving histograms) as JSON "
                         "after the run")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics snapshot JSON to a file")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler XLA trace of the run into "
                         "this directory (TensorBoard/Perfetto); the "
                         "scheduler's TraceAnnotation spans line up with "
                         "the device timeline")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import json

    import jax
    import numpy as np
    from repro.configs import get_config, get_smoke_config
    from repro.models import init_params
    from repro.serve import (PrefixCache, Request, RequestError, ServeEngine,
                             SessionStore, Telemetry, validate_chrome_trace)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if args.continuous and (args.temperature > 0 or args.top_k > 0):
        ap.error("--continuous streams greedy tokens; --temperature/--top-k "
                 "apply to single-batch mode only")
    mesh = None
    if args.mesh is not None:
        from repro.launch.mesh import parse_mesh
        mesh = parse_mesh(args.mesh)
        print(f"mesh: {dict(mesh.shape)} over {mesh.devices.size} "
              f"{mesh.devices.flat[0].platform} device(s)")
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    seg = cfg.armt.segment_len if cfg.armt else 64
    prefix_cache = (PrefixCache(seg, max_bytes=int(args.prefix_cache_mb * 2**20),
                                spill_dir=args.store_dir)
                    if args.prefix_cache else None)
    session_store = (SessionStore(max_bytes=int(args.session_mb * 2**20),
                                  spill_dir=args.store_dir)
                     if args.session_store else None)
    # headroom for the longer of the two continuous prompt buckets
    tel = Telemetry(trace=args.trace_out is not None)
    eng = ServeEngine(params, cfg, serve_mode=args.serve_mode,
                      schedule=args.schedule,
                      max_len=args.prompt_len + seg // 2 + args.max_new,
                      prefix_cache=prefix_cache, session_store=session_store,
                      mesh=mesh, telemetry=tel)

    def emit_telemetry():
        """--trace-out / --metrics[-out] epilogue shared by both modes."""
        if args.trace_out:
            tel.trace.export(args.trace_out)
            errs = validate_chrome_trace(args.trace_out)
            n = len(tel.trace.spans)
            if errs:
                raise SystemExit(f"trace schema check failed: {errs}")
            print(f"trace: {n} spans -> {args.trace_out}")
        if args.metrics or args.metrics_out:
            snap = eng.metrics_snapshot()
            if args.metrics:
                print("metrics:", json.dumps(snap, indent=2, default=str))
            if args.metrics_out:
                with open(args.metrics_out, "w") as f:
                    json.dump(snap, f, indent=2, default=str)
                print(f"metrics -> {args.metrics_out}")

    if args.profile_dir:
        jax.profiler.start_trace(args.profile_dir)

    if args.continuous:
        rng = np.random.default_rng(args.seed + 1)
        # two prompt-length buckets: heterogeneous segment phases without a
        # fresh prefill compile per request (cf. benchmarks/bench_serve.py)
        lens = [args.prompt_len if i % 2 == 0
                else max(1, args.prompt_len + seg // 2)
                for i in range(args.requests)]
        if prefix_cache is not None:
            # shared system prompt: every request begins with the same full
            # segments, so admissions after the first hit the cache
            n_sys = max(seg, (args.prompt_len // (2 * seg)) * seg)
            sys_prompt = rng.integers(8, cfg.vocab, (n_sys,)).astype("int32")
            reqs = [Request(
                req_id=f"r{i}",
                prompt=np.concatenate([
                    sys_prompt,
                    rng.integers(8, cfg.vocab,
                                 (max(1, L - n_sys),)).astype("int32")]),
                max_new=args.max_new)
                for i, L in enumerate(lens)]
        else:
            reqs = [Request(req_id=f"r{i}",
                            prompt=rng.integers(8, cfg.vocab,
                                                (L,)).astype("int32"),
                            max_new=args.max_new)
                    for i, L in enumerate(lens)]
        t0 = time.perf_counter()
        n_tok = 0
        outs = {r.req_id: [] for r in reqs}
        metrics = {}
        for ev in eng.serve(
                reqs, n_slots=args.slots, chunk=args.chunk,
                max_queue=args.max_queue,
                prefill_groups_per_chunk=args.prefill_groups_per_chunk,
                fused_admission=args.fused_admission,
                max_concurrent_admissions=args.max_concurrent_admissions,
                admission_fairness=args.admission_fairness):
            if isinstance(ev, RequestError):
                print(f"{ev.req_id}: REJECTED [{ev.code}] {ev.message}")
                continue
            n_tok += 1
            outs[ev.req_id].append(ev.token)
            if ev.done:
                metrics[ev.req_id] = (ev.ttft_s, ev.tok_s)
                print(f"{ev.req_id}: done ({ev.index + 1} tokens, "
                      f"ttft={ev.ttft_s:.2f}s, {ev.tok_s:.1f} tok/s) "
                      f"first 8: {outs[ev.req_id][:8]}")
        dt = time.perf_counter() - t0
        k = args.prefill_groups_per_chunk
        n_conc = args.max_concurrent_admissions
        adm = ("blocking" if k == 0 else
               "blocking(jitted stepper, whole stage per advance)" if k < 0
               else f"interleaved(k={k}"
                    f", N={'slots' if n_conc is None else n_conc}"
                    f", {args.admission_fairness}"
                    f"{', fused' if args.fused_admission else ''})")
        print(f"arch={cfg.name} mode={args.serve_mode} slots={args.slots} "
              f"requests={args.requests} admission={adm}")
        print(f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s)")
        if prefix_cache is not None:
            st = prefix_cache.stats.as_dict()
            print(f"prefix-cache: {st['hits']} hits / {st['misses']} misses, "
                  f"{len(prefix_cache)} entries, "
                  f"{st['bytes_in_ram'] / 2**10:.1f} KiB, "
                  f"{st['evictions']} evictions ({st['spills']} spilled)")
        if args.profile_dir:
            jax.profiler.stop_trace()
            print(f"xla profile -> {args.profile_dir}")
        emit_telemetry()
        return

    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                 (args.batch, args.prompt_len), 8, cfg.vocab)
    if session_store is not None:
        # two-turn session demo on row 0: turn 2 feeds only the new tokens
        turn2 = jax.random.randint(jax.random.PRNGKey(args.seed + 2),
                                   (1, max(8, seg // 2)), 8, cfg.vocab)
        r1 = eng.generate(prompts[:1], args.max_new, session_id="demo")
        r2 = eng.generate(turn2, args.max_new, session_id="demo")
        print(f"arch={cfg.name} session demo: turn1 ttft={r1.ttft_s:.2f}s "
              f"({prompts.shape[1]} prompt tokens), turn2 resumed="
              f"{r2.resumed} ttft={r2.ttft_s:.2f}s "
              f"({turn2.shape[1]} new tokens, history never recomputed)")
        print("turn2 first 8:", r2.tokens[0, :8].tolist())
        if args.profile_dir:
            jax.profiler.stop_trace()
            print(f"xla profile -> {args.profile_dir}")
        emit_telemetry()
        return

    t0 = time.perf_counter()
    res = eng.generate(prompts, args.max_new, temperature=args.temperature,
                       top_k=args.top_k, seed=args.seed)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} mode={args.serve_mode} schedule={res.schedule} "
          f"prefill_segments={res.prefill_segments}")
    print(f"generated {res.tokens.shape} tokens in {dt:.2f}s "
          f"(ttft={res.ttft_s:.2f}s, decode {res.tok_s:.1f} tok/s)")
    print("first row:", res.tokens[0].tolist())
    if args.profile_dir:
        jax.profiler.stop_trace()
        print(f"xla profile -> {args.profile_dir}")
    emit_telemetry()


if __name__ == "__main__":
    main()
