"""Small shared utilities (no jax imports at module scope beyond jax itself)."""
from __future__ import annotations

import functools
import math
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def round_up(x: int, m: int) -> int:
    """Smallest multiple of m that is >= x."""
    return ((x + m - 1) // m) * m


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def tree_bytes(tree: Any) -> int:
    """Total bytes of a pytree of arrays / ShapeDtypeStructs."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize for l in leaves)


def tree_params(tree: Any) -> int:
    """Total element count of a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) for l in leaves)


def fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB", "PiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}EiB"


def fmt_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"


class Timer:
    """Context-manager wall timer: with Timer() as t: ...; t.s"""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.s = time.perf_counter() - self.t0
        return False


def timeit_median(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time of fn(*args) with block_until_ready on the output."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def cast_tree(tree: Any, dtype) -> Any:
    """Cast all floating leaves of a pytree to dtype."""
    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(_cast, tree)
