"""AdamW + schedules + global-norm clipping, pure JAX (no optax dependency).

Mixed precision: params may be bf16; moments kept in `moment_dtype`
(fp32 default; bf16 for the 1T-param MoE to fit ZeRO-1 on 512 chips —
DESIGN.md §4); the update math runs in fp32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"
    # Adafactor-style factored second moment for >=2D leaves: v is stored as
    # row/col running means (O(n+m) instead of O(n*m)) — required to fit the
    # 1T-param MoE's optimizer state on 512 chips (DESIGN.md §4)
    factored_v: bool = False


def lr_schedule(ocfg: OptimConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(ocfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - ocfg.warmup_steps)
                    / jnp.maximum(ocfg.total_steps - ocfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = ocfg.min_lr_ratio + (1 - ocfg.min_lr_ratio) * cos
    return ocfg.lr * warm * scale


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def _is_factored(p, ocfg: OptimConfig) -> bool:
    return ocfg.factored_v and p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def adamw_init(params: Any, ocfg: OptimConfig) -> Dict:
    mdt = jnp.dtype(ocfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)

    def v_init(p):
        if _is_factored(p, ocfg):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return jnp.zeros(p.shape, mdt)

    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(v_init, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params: Any, grads: Any, opt: Dict,
                 ocfg: OptimConfig) -> Tuple[Any, Dict, Dict]:
    """Returns (new_params, new_opt, metrics)."""
    if ocfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, ocfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = opt["step"] + 1
    lr = lr_schedule(ocfg, step)
    b1, b2 = ocfg.b1, ocfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(ocfg.moment_dtype)

    def new_m_fn(g, m):
        return (b1 * m.astype(jnp.float32)
                + (1 - b1) * g.astype(jnp.float32)).astype(mdt)

    def new_v_fn(g, v):
        g32 = g.astype(jnp.float32)
        if isinstance(v, dict):   # factored (Adafactor-style)
            g2 = g32 * g32 + 1e-30
            return {"vr": b2 * v["vr"] + (1 - b2) * g2.mean(-1),
                    "vc": b2 * v["vc"] + (1 - b2) * g2.mean(-2)}
        return (b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32).astype(mdt)

    is_v_leaf = lambda x: isinstance(x, dict) and set(x) == {"vr", "vc"}
    new_m = jax.tree_util.tree_map(new_m_fn, grads, opt["m"])

    # v may contain factored {vr, vc} sub-dicts where params have one leaf:
    # flatten with those as leaves so the structures line up
    tu = jax.tree_util
    g_leaves, g_def = tu.tree_flatten(grads)
    v_leaves, _ = tu.tree_flatten(opt["v"], is_leaf=is_v_leaf)
    new_v_leaves = [new_v_fn(g, v) for g, v in zip(g_leaves, v_leaves)]
    new_v = tu.tree_unflatten(g_def, new_v_leaves)

    def vhat_of(v):
        if isinstance(v, dict):
            vr, vc = v["vr"], v["vc"]
            return (vr[..., None] * vc[..., None, :]
                    / (vr.mean(-1)[..., None, None] + 1e-30)) / bc2
        return v.astype(jnp.float32) / bc2

    def new_p_fn(p, m, v):
        mh = m.astype(jnp.float32) / bc1
        delta = mh / (jnp.sqrt(vhat_of(v)) + ocfg.eps)
        p32 = p.astype(jnp.float32)
        return (p32 - lr * (delta + ocfg.weight_decay * p32)).astype(p.dtype)

    p_leaves = tu.tree_leaves(params)
    m_leaves = tu.tree_leaves(new_m)
    new_p_leaves = [new_p_fn(p, m, v) for p, m, v in
                    zip(p_leaves, m_leaves, new_v_leaves)]
    new_params = tu.tree_unflatten(g_def, new_p_leaves)
    new_opt = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_opt, {"lr": lr, "grad_norm": gnorm}
