from repro.optim.adamw import (OptimConfig, adamw_init, adamw_update,
                               lr_schedule, global_norm, clip_by_global_norm)
