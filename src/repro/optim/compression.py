"""Int8 error-feedback gradient compression for cross-pod reduction.

The cross-pod ICI/DCN hop is the slowest link in a multi-pod mesh; gradients
tolerate aggressive quantization if the quantization error is fed back into
the next step (error-feedback / EF-SGD). Scheme per leaf:

  g_eff = g + e_prev                 (error feedback)
  q, scale = quantize_int8(g_eff)    (per-tile max-abs scaling)
  e_next = g_eff - dequant(q, scale) (local; carried in opt state)
  sync: all-reduce/all-gather of q (1 byte/elem) + scales (fp32/tile)
        instead of bf16/fp32 full gradients -> 2-4x wire-byte reduction
        on the cross-pod axis.

`compressed_psum` implements the sync inside shard_map over a named axis:
int8 all-gather + local dequant-sum (int8 summation would overflow), which
costs (n-1)/n * bytes * 1 per device vs 2 * (n-1)/n * bytes * 2 for a bf16
ring all-reduce — ~4x wire reduction for n=2 pods.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp

TILE = 256


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tile symmetric int8 quantization along the last axis.
    Returns (q int8 [..., n], scale fp32 [..., n/TILE])."""
    shape = x.shape
    n = shape[-1]
    pad = (-n) % TILE
    xf = x.astype(jnp.float32)
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    t = xf.reshape(shape[:-1] + (-1, TILE))
    scale = jnp.max(jnp.abs(t), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(t / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(shape[:-1] + (n + pad,))[..., :n + pad], scale


def dequantize_int8(q: jax.Array, scale: jax.Array, n: int) -> jax.Array:
    t = q.reshape(q.shape[:-1] + (-1, TILE)).astype(jnp.float32)
    x = (t * scale[..., None]).reshape(q.shape[:-1] + (-1,))
    return x[..., :n]


def ef_compress(g: jax.Array, err: jax.Array):
    """One error-feedback round trip (local). Returns (g_hat, new_err)."""
    g_eff = g.astype(jnp.float32) + err
    q, s = quantize_int8(g_eff)
    g_hat = dequantize_int8(q, s, g.shape[-1]).astype(g.dtype)
    return g_hat, (g_eff - g_hat.astype(jnp.float32))


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Quantized cross-axis sum (use inside shard_map): all-gather int8 +
    scales, dequantize and sum locally."""
    n = x.shape[-1]
    q, s = quantize_int8(x)
    q_all = jax.lax.all_gather(q, axis_name)          # [n_dev, ..., n_pad]
    s_all = jax.lax.all_gather(s, axis_name)
    deq = jax.vmap(lambda qq, ss: dequantize_int8(qq, ss, n))(q_all, s_all)
    return jnp.sum(deq, axis=0).astype(x.dtype)


def wire_bytes_ratio(n_devices: int) -> float:
    """Wire bytes of compressed_psum vs bf16 ring all-reduce (per device)."""
    ag = (n_devices - 1) / n_devices * (1 + 4 / TILE)     # int8 + scales
    ar = 2 * (n_devices - 1) / n_devices * 2              # bf16 ring AR
    return ag / ar
