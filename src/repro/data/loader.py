"""Device placement of host batches: shard over the mesh DP axes."""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from repro.parallel import sharding as shd


def shard_batch(batch: Dict[str, np.ndarray], mesh=None):
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    from jax.sharding import NamedSharding, PartitionSpec as P
    out = {}
    for k, v in batch.items():
        ax = shd.batch_axes(mesh, v.shape[0])
        ns = NamedSharding(mesh, P(ax, *([None] * (v.ndim - 1))))
        out[k] = jax.device_put(v, ns)
    return out
