"""Synthetic data: a learnable LM stream and a BABILong-style needle-QA task.

The needle task is the quality probe for ARMT memory (paper Tables 3/4): a
(key, value) fact is planted in filler text, the query comes at the end —
long-context accuracy requires carrying the fact across segments in memory.
All generators are deterministic in (seed, index) for exact resume after
restart (fault tolerance: data order is reproducible from the step counter).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

# reserved control tokens (vocab must be > 16)
PAD, BOS, FACT, QUERY, ANSWER = 0, 1, 2, 3, 4
N_RESERVED = 8


def lm_stream(vocab: int, batch: int, seq_len: int, *, seed: int = 0,
              start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Markov-chain token stream — learnable structure for loss-drop tests."""
    V = vocab - N_RESERVED
    rng0 = np.random.default_rng(seed)
    trans = rng0.dirichlet(np.ones(64) * 0.1, size=V)   # sparse transitions
    nxt = np.argsort(-trans, axis=1)[:, :64]
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step))
        toks = np.zeros((batch, seq_len + 1), np.int64)
        toks[:, 0] = rng.integers(0, V, batch)
        choice = rng.integers(0, 64, (batch, seq_len))
        explore = rng.random((batch, seq_len)) < 0.1
        rand = rng.integers(0, V, (batch, seq_len))
        for t in range(seq_len):
            nt = nxt[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(explore[:, t], rand[:, t], nt)
        toks += N_RESERVED
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        step += 1


def needle_qa(vocab: int, batch: int, seq_len: int, *, seed: int = 0,
              start_step: int = 0, n_keys: int = 64,
              needle_region: Optional[tuple] = None
              ) -> Iterator[Dict[str, np.ndarray]]:
    """[BOS] filler... [FACT key value] filler... [QUERY key ANSWER] -> value.

    Loss is masked to the answer position only; 'answer' field gives the
    gold token for exact-match accuracy.
    """
    V = vocab - N_RESERVED
    n_keys = min(n_keys, V // 2)
    keys = np.arange(n_keys) + N_RESERVED
    vals_base = n_keys
    step = start_step
    lo, hi = needle_region or (0.05, 0.7)
    while True:
        rng = np.random.default_rng((seed, step, 17))
        toks = rng.integers(2 * n_keys + N_RESERVED, max(V, 2 * n_keys + 9)
                            + N_RESERVED, (batch, seq_len)).astype(np.int64)
        ki = rng.integers(0, n_keys, batch)
        key = keys[ki]
        val = (vals_base + rng.integers(0, n_keys, batch) + N_RESERVED)
        pos = rng.integers(int(seq_len * lo), int(seq_len * hi), batch)
        rows = np.arange(batch)
        toks[:, 0] = BOS
        toks[rows, pos] = FACT
        toks[rows, pos + 1] = key
        toks[rows, pos + 2] = val
        toks[rows, seq_len - 3] = QUERY
        toks[rows, seq_len - 2] = key
        toks[rows, seq_len - 1] = ANSWER
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = val                    # predict value after ANSWER
        mask = np.zeros((batch, seq_len), np.float32)
        mask[rows, seq_len - 1] = 1.0
        yield {"tokens": toks.astype(np.int32),
               "labels": labels.astype(np.int32),
               "loss_mask": mask,
               "answer": val.astype(np.int32)}
        step += 1
