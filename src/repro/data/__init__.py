from repro.data.synthetic import lm_stream, needle_qa, N_RESERVED, ANSWER
from repro.data.loader import shard_batch
