"""§Perf hillclimb driver — lowers cell *variants* and records the roofline
deltas (hypothesis -> change -> before -> after in EXPERIMENTS.md §Perf).

Variants:
  slot_shard   diagonal-as-pipeline: slots sharded over a 'stage' axis,
               per-layer weights fully local, shift -> collective-permute.
               Mesh (data, stage) replaces (data, model).
  slot_tp      hybrid: (data, stage, model) — slots over stage, residual TP
               over a small model axis (for archs whose dims need it).
  seq_prefill  prefill with the sequential schedule (paper baseline ARMT).

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb --exp danube_slot8
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("PREPEND_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax import ShapeDtypeStruct as SDS                      # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402

from repro.configs import SHAPES, get_config                 # noqa: E402
from repro.launch.dryrun import measure                      # noqa: E402
from repro.launch.specs import Cell, build_cell              # noqa: E402
from repro.models import forward_hidden, last_logits         # noqa: E402
from repro.models.model import param_specs as mps            # noqa: E402
from repro.parallel import sharding as shd                   # noqa: E402
from repro.roofline import model_flops                       # noqa: E402

OUT = Path("artifacts/hillclimb")


def _run(name: str, mesh, cell: Cell, mf: float):
    record = {"arch": cell.arch, "shape": cell.shape, "tag": name,
              "mesh": "x".join(str(s) for s in mesh.devices.shape),
              "n_devices": mesh.size, "ok": False, "meta": cell.meta}
    t0 = time.time()
    try:
        measure(mesh, cell, mf, record)
    except Exception as e:
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-3000:]
    record["total_s"] = round(time.time() - t0, 2)
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / f"{name}.json").write_text(json.dumps(record, indent=1,
                                                 default=str))
    if record["ok"]:
        r = record["roofline"]
        print(f"[OK ] {name}: comp={r['compute_s']:.3e} mem={r['memory_s']:.3e}"
              f" coll={r['collective_s']:.3e} dom={r['dominant']}"
              f" frac={r['roofline_fraction']:.4f}", flush=True)
    else:
        print(f"[FAIL] {name}: {record.get('error', '')[:200]}", flush=True)
    return record


def slot_shard_prefill(arch: str, *, stage: int, data: int,
                       tp: int = 1, schedule: str = "diagonal",
                       attn_impl: str = "dense",
                       moe_dispatch: str = None,
                       name: str = "") -> dict:
    """Prefill cell with the slot dim sharded over a 'stage' axis."""
    import dataclasses
    cfg = dataclasses.replace(get_config(arch), attn_impl=attn_impl)
    if moe_dispatch and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=moe_dispatch))
    shape = SHAPES["prefill_32k"]
    axes = [("data", data), ("stage", stage)]
    if tp > 1:
        axes.append(("model", tp))
    assert data * stage * tp == 256, (data, stage, tp)
    mesh = jax.make_mesh(tuple(s for _, s in axes), tuple(a for a, _ in axes))

    dp = "data"
    slot_spec = P("stage", dp if shape.global_batch % data == 0 else None,
                  None, None)

    def prefill(params, batch):
        hidden, fin = forward_hidden(params, cfg, batch["tokens"],
                                     schedule=schedule, slot_spec=slot_spec)
        return last_logits(params, cfg, hidden), fin

    pshape = mps(cfg)
    with mesh:
        pspecs = shd.param_specs(pshape, mesh, stacked_axis="stage")
        batch = {"tokens": SDS((shape.global_batch, shape.seq_len), jnp.int32)}
        bspecs = {"tokens": NamedSharding(
            mesh, P(dp if shape.global_batch % data == 0 else None, None))}
    cell = Cell(arch, "prefill_32k", prefill, (pshape, batch),
                (pspecs, bspecs), None,
                {"kind": "prefill", "schedule": schedule,
                 "variant": f"slot_shard stage={stage} data={data} tp={tp}"})
    return _run(name or f"{arch}__prefill32k__slot{stage}", mesh, cell,
                model_flops(cfg, shape))


EXPERIMENTS = {
    # cell 3 (paper-representative): danube prefill, diagonal schedule
    "danube_base": lambda: _baseline("h2o-danube-1.8b", "prefill_32k",
                                     schedule="diagonal"),
    "danube_seq": lambda: _baseline("h2o-danube-1.8b", "prefill_32k",
                                    schedule="sequential"),
    "danube_slot8": lambda: slot_shard_prefill(
        "h2o-danube-1.8b", stage=8, data=32),
    "danube_slot8_tp2": lambda: slot_shard_prefill(
        "h2o-danube-1.8b", stage=8, data=16, tp=2),
    "danube_slot8_chunked": lambda: slot_shard_prefill(
        "h2o-danube-1.8b", stage=8, data=32, attn_impl="chunked",
        name="h2o-danube-1.8b__prefill32k__slot8_chunked"),
    "qwen32b_slot16": lambda: slot_shard_prefill(
        "qwen2.5-32b", stage=16, data=16),
    "chameleon_slot16": lambda: slot_shard_prefill(
        "chameleon-34b", stage=16, data=16),
    # MoE under slot sharding: each stage owns whole layers => expert
    # weights AND dispatch fully local (no EP all-to-all at all)
    "qwen2moe_slot8": lambda: slot_shard_prefill(
        "qwen2-moe-a2.7b", stage=8, data=32),
    "qwen2moe_slot8_perrow": lambda: slot_shard_prefill(
        "qwen2-moe-a2.7b", stage=8, data=32, moe_dispatch="per_row",
        name="qwen2-moe-a2.7b__prefill32k__slot8_perrow"),
    "qwen2moe_slot8_einsum": lambda: slot_shard_prefill(
        "qwen2-moe-a2.7b", stage=8, data=32, moe_dispatch="einsum",
        name="qwen2-moe-a2.7b__prefill32k__slot8_einsum"),
    "minitron_slot16": lambda: slot_shard_prefill(
        "minitron-8b", stage=16, data=16),
    "whisper_slot8": lambda: slot_shard_prefill(
        "whisper-medium", stage=8, data=32),
    # cell 1: kimi train — v2 sweep already applies fsdp/factored/microbatch;
    # variants probed here
    "kimi_train_mb16": lambda: _baseline("kimi-k2-1t-a32b", "train_4k",
                                         microbatches=16),
    # cell 2: falcon train — ssm method comparison is in the main sweep
    "falcon_prefill_slot16": lambda: slot_shard_prefill(
        "falcon-mamba-7b", stage=16, data=16),
}


def _baseline(arch, shape, **kw):
    from repro.launch.dryrun import run_cell
    return run_cell(arch, shape, multi_pod=False,
                    save_dir=OUT, tag="_" + "_".join(
                        f"{k}={v}" for k, v in kw.items()) if kw else "_base",
                    **kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", required=True,
                    help=f"one of {sorted(EXPERIMENTS)} or comma list")
    args = ap.parse_args()
    for e in args.exp.split(","):
        EXPERIMENTS[e]()


if __name__ == "__main__":
    main()
