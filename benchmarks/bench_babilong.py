"""Paper Tables 3/4 (BABILong stand-in): needle-QA accuracy + generation
time with the original sequential ARMT vs Diagonal Batching.

Trains a reduced ARMT on the synthetic needle task with a mixed needle
region spanning a segment boundary (single-boundary curriculum — the full
paper setup trains to 8k with curriculum; at CPU scale this demonstrates the
same thing: retrieval *through the associative memory*, needle in an earlier
segment than the query). Then evaluates:
  (a) exact-match accuracy under both schedules — quality must be preserved
      (paper Table 3),
  (b) forward wall time sequential vs diagonal (paper Table 4)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.configs import ARMTConfig, get_smoke_config
from repro.data import needle_qa
from repro.models import forward_hidden, last_logits
from repro.optim import OptimConfig
from repro.train.loop import train_loop

SEG = 32


def _cfg():
    return dataclasses.replace(
        get_smoke_config("llama-1b-armt"),
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
        armt=ARMTConfig(segment_len=SEG, num_mem_tokens=8, d_mem=8))


def main(quick: bool = True):
    cfg = _cfg()
    steps = 600          # below ~500 steps retrieval stays at chance
    ocfg = OptimConfig(lr=3e-3, total_steps=steps, warmup_steps=10,
                       weight_decay=0.0)
    data = needle_qa(cfg.vocab, 32, 4 * SEG, seed=0, n_keys=4,
                     needle_region=(0.55, 0.95))
    out = train_loop(cfg, ocfg, data, steps=steps, schedule="sequential")
    params = out["state"]["params"]
    row("babilong_train_final_loss", 0.0,
        f"loss={out['history'][-1]['loss']:.4f};steps={steps}")

    # Table 3: accuracy, same-segment and cross-segment needles, both schedules
    for region, name in [((0.80, 0.92), "same_seg"), ((0.55, 0.72), "prev_seg")]:
        test = next(needle_qa(cfg.vocab, 64, 4 * SEG, seed=999, n_keys=4,
                              needle_region=region))
        toks = jnp.asarray(test["tokens"])
        gold = np.asarray(test["answer"])
        accs = {}
        for sched in ("sequential", "diagonal"):
            fwd = jax.jit(lambda p, t, s=sched: last_logits(
                p, cfg, forward_hidden(p, cfg, t, schedule=s)[0]))
            pred = np.asarray(jnp.argmax(fwd(params, toks), -1))
            accs[sched] = float((pred == gold).mean())
            row(f"babilong_acc_{name}_{sched}", 0.0,
                f"exact_match={accs[sched]:.3f};chance=0.25")
        row(f"babilong_quality_{name}", 0.0,
            f"schedules_agree={abs(accs['sequential'] - accs['diagonal']) < 0.05}")

    # Table 4: generation (forward) time across lengths
    for n_seg in (4, 8) if quick else (4, 8, 16, 32):
        L = n_seg * SEG
        test = next(needle_qa(cfg.vocab, 32, L, seed=123, n_keys=4))
        toks = jnp.asarray(test["tokens"])
        ts = {}
        for sched in ("sequential", "diagonal"):
            fwd = jax.jit(lambda p, t, s=sched: last_logits(
                p, cfg, forward_hidden(p, cfg, t, schedule=s)[0]))
            ts[sched] = timeit(fwd, params, toks, warmup=1, iters=2)
            row(f"babilong_time_{sched}_L{L}", ts[sched], "")
        row(f"babilong_speedup_L{L}", 0.0,
            f"diag_vs_seq={ts['sequential'] / ts['diagonal']:.2f}x")


if __name__ == "__main__":
    main()
