"""Paper Fig. 5: attention throughput improves when the diagonal group acts
as a batch dim — time per segment vs group size."""
from __future__ import annotations

import jax
import jax.random as jr

from benchmarks.common import row, timeit
from repro.kernels import ref


def main(quick: bool = True):
    H, T, hd = 8, 256 if quick else 1024, 64
    key = jr.PRNGKey(0)
    att = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True))

    base = None
    for g in (1, 2, 4, 8, 16):
        q = jr.normal(key, (g, H, T, hd))
        k = jr.normal(key, (g, H, T, hd))
        v = jr.normal(key, (g, H, T, hd))
        t = timeit(att, q, k, v) / g
        if base is None:
            base = t
        row(f"attention_group{g}", t, f"speedup_per_seg_vs_g1={base / t:.2f}")


if __name__ == "__main__":
    main()
