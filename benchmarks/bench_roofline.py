"""§Roofline: aggregate the dry-run artifacts into the per-(arch x shape x
mesh) roofline table — the three terms, dominant bottleneck, useful-FLOPs
ratio and roofline fraction."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import row

ART = Path("artifacts/dryrun")


def main(quick: bool = True):
    files = sorted(ART.glob("*.json"))
    if not files:
        row("roofline_missing", 0.0, "run repro.launch.dryrun first")
        return
    n_ok = 0
    for f in files:
        r = json.loads(f.read_text())
        name = f"{r['arch']}|{r['shape']}|{r['mesh']}{r.get('tag', '')}"
        if not r.get("ok"):
            row(f"roofline_{name}", 0.0, "FAILED")
            continue
        n_ok += 1
        rf = r["roofline"]
        dom_s = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        row(f"roofline_{name}", dom_s,
            f"comp={rf['compute_s']:.3e};mem={rf['memory_s']:.3e};"
            f"coll={rf['collective_s']:.3e};dom={rf['dominant']};"
            f"useful={rf['useful_ratio']:.3f};"
            f"frac={rf['roofline_fraction']:.4f}")
    row("roofline_cells_ok", 0.0, f"count={n_ok}/{len(files)}")


if __name__ == "__main__":
    main()
