"""Kernel dispatch + autotune benchmark -> ``BENCH_kernels.json``
(EXPERIMENTS.md §Kernels).

Per kernel entry point (kernels/dispatch.OPS) at model-scale shapes (the
bench_diagonal configuration: d_model 64, 4x16 heads, d_ff 128, group of 8
layers, 128-token segments + 8 memory tokens):

* sweeps the backend's config space through the Autotuner (paired timing)
  and records the ranked table + the winning config — on CPU the space is
  the XLA singleton by design, on TPU/GPU this is the real block-size sweep;
* fills the dispatch disk cache, so serving processes on this machine
  cold-start into the tuned winners;
* times the XLA-native path against the same op forced through
  pallas-interpret at small shapes — the measured reason dispatch sends CPU
  to XLA instead of paying interpret overhead (ROADMAP item: the fused path
  must win on the hardware it actually runs on, not just in kernel-land).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.kernels import dispatch
from repro.kernels.autotune import Autotuner, run_op
from repro.kernels.dispatch import KernelConfig
from repro.serve.telemetry import default_registry

G, T, D, HD, NH, NKV, DFF, M, DM, NU = 8, 136, 64, 16, 4, 4, 128, 8, 8, 3


def _model_args(op: str, quick: bool):
    """Model-scale operand sets (bench_diagonal dims; x4 rows when not
    quick so CPU timings have signal)."""
    t = T if quick else 4 * T
    ks = jax.random.split(jax.random.PRNGKey(0), 10)
    if op == "grouped_matmul":
        return (jax.random.normal(ks[0], (G, t, D)),
                jax.random.normal(ks[1], (G, D, 3 * NH * HD))), {}
    if op == "grouped_matmul_armt_update":
        P = 2 * NU * DM
        return (jax.random.normal(ks[0], (G, t, DFF)) * 0.3,
                jax.random.normal(ks[1], (G, DFF, D)) * 0.3,
                jax.random.normal(ks[2], (G, t, D)) * 0.3,
                jax.random.normal(ks[3], (G, D, DM)) * 0.3,
                jax.random.normal(ks[4], (G, D, D)) * 0.3,
                jax.random.normal(ks[5], (G, D, 1)) * 0.3,
                jax.random.normal(ks[6], (G, P, D)) * 0.1,
                jax.random.normal(ks[7], (G, P)) * 0.1), {"M": M, "nu": NU}
    if op == "flash_attention":
        # 5-D grouped-block layout [G, B, T, H, hd] — what the fused
        # diagonal path dispatches, and the layout on which the CPU
        # XLA-variant candidates (fast_softmax / causal_blocks) engage
        return (jax.random.normal(ks[0], (G, 1, t, NH, HD)),
                jax.random.normal(ks[1], (G, 1, t, NKV, HD)),
                jax.random.normal(ks[2], (G, 1, t, NKV, HD))), {}
    if op == "decode_attention":
        B, S = 16, 1024 if not quick else 256
        return (jax.random.normal(ks[0], (B, NH, HD)),
                jax.random.normal(ks[1], (B, S, NKV, HD)),
                jax.random.normal(ks[2], (B, S, NKV, HD)),
                jnp.arange(1, B + 1, dtype=jnp.int32) * (S // B)), {}
    if op == "armt_read":
        P = 2 * NU * DM
        return (jax.random.normal(ks[0], (G, t, D)),
                jax.random.normal(ks[1], (D, DM)) * 0.3,
                jax.random.normal(ks[2], (G, P, D)) * 0.1,
                jax.random.uniform(ks[3], (G, P))), {"nu": NU}
    if op == "armt_update":
        P = 2 * NU * DM
        return (jax.random.normal(ks[0], (G, M, D)),
                jax.random.normal(ks[1], (D, DM)) * 0.3,
                jax.random.normal(ks[2], (D, D)) * 0.3,
                jax.random.normal(ks[3], (D, 1)) * 0.3,
                jax.random.normal(ks[4], (G, P, D)) * 0.1,
                jax.random.uniform(ks[5], (G, P))), {"nu": NU}
    if op == "mamba_scan":
        dS = 16
        return (jax.random.normal(ks[0], (1, t, D)) * 0.5,
                jax.nn.softplus(jax.random.normal(ks[1], (1, t, D))),
                jax.random.normal(ks[2], (1, t, dS)) * 0.5,
                jax.random.normal(ks[3], (1, t, dS)) * 0.5,
                jnp.log(jnp.tile(jnp.arange(1., dS + 1)[None], (D, 1))),
                jnp.ones(D),
                jax.random.normal(ks[4], (1, D, dS)) * 0.1), {}
    raise ValueError(op)


def _tiny_args(op: str):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    if op == "grouped_matmul":
        return (jax.random.normal(ks[0], (2, 32, 32)),
                jax.random.normal(ks[1], (2, 32, 32))), {}
    assert op == "armt_read"
    P = 2 * NU * DM
    return (jax.random.normal(ks[0], (2, 16, D)),
            jax.random.normal(ks[1], (D, DM)) * 0.3,
            jax.random.normal(ks[2], (2, P, D)) * 0.1,
            jax.random.uniform(ks[3], (2, P))), {"nu": NU}


def _interpret_overhead(op: str):
    """XLA vs pallas-interpret seconds at tiny shapes: the measured basis
    for the CPU heuristic row (dispatch sends cpu -> xla)."""
    args, kw = _tiny_args(op)
    interp_cfg = KernelConfig(impl="pallas", interpret=True)
    xla = timeit(jax.jit(lambda *a: run_op(op, a, dispatch.XLA, **kw)),
                 *args, warmup=1, iters=3)
    interp = timeit(jax.jit(lambda *a: run_op(op, a, interp_cfg, **kw)),
                    *args, warmup=1, iters=3)
    return xla, interp


def bench_kernels(quick: bool = True, out_path: str | None = None):
    backend = jax.default_backend()
    tuner = Autotuner(registry=default_registry())
    results = []
    for op in dispatch.OPS:
        args, kw = _model_args(op, quick)
        ranked = tuner.sweep(op, args, repeats=2 if quick else 5,
                             op_kwargs=kw)
        winner = tuner.get_or_tune(op, args, op_kwargs=kw)
        rec = {
            "op": op,
            "key": tuner.key_for(op, args),
            "winner": winner.to_json(),
            "sweep": [{"config": c.to_json(), "s": t} for c, t in ranked],
        }
        if ranked:
            row(f"kernel_{op}_best", ranked[0][1],
                f"{len(ranked)} candidates impl={winner.impl}")
        # quick mode times the interpret overhead for two representative
        # ops only (interpret lowering is slow by design)
        if op in ("grouped_matmul", "armt_read"):
            xla_s, interp_s = _interpret_overhead(op)
            rec["xla_s_tiny"] = xla_s
            rec["pallas_interpret_s_tiny"] = interp_s
            rec["interpret_overhead_x"] = interp_s / xla_s
            row(f"kernel_{op}_interpret_overhead", interp_s,
                f"{interp_s / xla_s:.0f}x vs xla")
        results.append(rec)

    out_path = out_path or os.environ.get("BENCH_KERNELS_OUT",
                                          "BENCH_kernels.json")
    payload = {
        "bench": "kernel_autotune",
        "backend": backend,
        "cache_path": dispatch.default_cache_path(),
        "model_dims": {"group": G, "seg_tokens": T, "d_model": D,
                       "heads": f"{NH}x{HD}", "d_ff": DFF,
                       "num_mem_tokens": M},
        "results": results,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    row("bench_kernels_json", 0.0, out_path)
    return payload


def main(quick: bool = True):
    bench_kernels(quick)


if __name__ == "__main__":
    main(quick=False)
