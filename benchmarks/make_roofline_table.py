"""Render the EXPERIMENTS.md roofline tables from artifacts/dryrun/*.json."""
from __future__ import annotations

import json
import sys
from pathlib import Path

from repro.configs import ASSIGNED_ARCHS, SHAPES

ART = Path("artifacts/dryrun")


def fmt(x, digits=3):
    if x == 0:
        return "0"
    return f"{x:.{digits}e}"


def load(mesh: str, tag: str = ""):
    out = {}
    for f in ART.glob(f"*__{mesh}{tag}.json"):
        r = json.loads(f.read_text())
        if r.get("tag", "") != tag:
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def table(mesh: str, tag: str = "", file=sys.stdout):
    rows = load(mesh, tag)
    print(f"\n### Mesh {mesh}{(' [' + tag + ']') if tag else ''}", file=file)
    print("| arch | shape | sched/mode | compute s | memory s | collective s "
          "| dominant | MODEL_FLOPs | useful | roofline frac | mem/dev |",
          file=file)
    print("|---|---|---|---|---|---|---|---|---|---|---|", file=file)
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            r = rows.get((arch, shape))
            if r is None:
                continue
            if not r["ok"]:
                print(f"| {arch} | {shape} | — | FAILED ({r.get('error','')[:40]}) "
                      "| | | | | | | |", file=file)
                continue
            rf = r["roofline"]
            meta = r.get("meta", {})
            sched = meta.get("schedule") or meta.get("serve_mode", "")
            mem = r.get("memory", {}) or {}
            mem_dev = sum(v for k, v in mem.items()
                          if isinstance(v, (int, float)) and k != "generated_code_bytes")
            print(f"| {arch} | {shape} | {sched} | {fmt(rf['compute_s'])} "
                  f"| {fmt(rf['memory_s'])} | {fmt(rf['collective_s'])} "
                  f"| **{rf['dominant']}** | {fmt(rf['model_flops'], 2)} "
                  f"| {rf['useful_ratio']:.2f} | {rf['roofline_fraction']:.4f} "
                  f"| {mem_dev / 2**30:.1f}GiB |", file=file)


if __name__ == "__main__":
    tag = sys.argv[1] if len(sys.argv) > 1 else ""
    table("16x16", tag)
    table("2x16x16", tag)
