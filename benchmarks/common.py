"""Shared benchmark helpers. Every bench prints ``name,us_per_call,derived``
CSV rows (the run.py contract)."""
from __future__ import annotations

import time

import jax
import numpy as np


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median seconds per call with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def paired_median(runs: dict, metric: str, num: str, den: str) -> float:
    """Median of per-rep ratios ``runs[num][i][metric] / runs[den][i][metric]``.

    ``runs`` maps mode name -> list of per-rep record dicts, collected
    round-robin (A/B, A/B, ...) so each rep's pair shares the same host
    conditions: load drift on a shared box cancels within a rep but not
    across per-mode aggregates. The headline ratio of every paired bench
    (mixed_workload, burst_admission, telemetry overhead) goes through
    this helper."""
    n = min(len(runs[num]), len(runs[den]))
    return float(np.median([runs[num][i][metric] / runs[den][i][metric]
                            for i in range(n)]))


def compiled_memory_stats(jit_fn, *args) -> dict:
    """AOT-compile ``jit_fn`` for ``args`` (arrays or ShapeDtypeStructs) and
    return ``memory_analysis()`` byte counts as
    ``{argument,output,temp,peak}_bytes`` — the launch/dryrun.py pattern:
    every field is getattr-guarded (backends differ in what they report;
    CPU has argument/output/temp but no peak, so peak falls back to their
    sum — an upper bound under whole-program liveness). Missing values stay
    None so JSON artifacts show *that* the backend withheld them rather
    than fabricating zeros."""
    compiled = jit_fn.lower(*args).compile()
    stats = {"argument_bytes": None, "output_bytes": None,
             "temp_bytes": None, "peak_bytes": None}
    try:
        ma = compiled.memory_analysis()
    except Exception:           # backend without memory_analysis support
        return stats
    arg = getattr(ma, "argument_size_in_bytes", None)
    out = getattr(ma, "output_size_in_bytes", None)
    temp = getattr(ma, "temp_size_in_bytes", None)
    peak = getattr(ma, "peak_memory_in_bytes", None)
    if peak is None and None not in (arg, out, temp):
        peak = arg + out + temp
    return {"argument_bytes": arg, "output_bytes": out,
            "temp_bytes": temp, "peak_bytes": peak}


def row(name: str, seconds: float, derived: str = "") -> str:
    line = f"{name},{seconds * 1e6:.1f},{derived}"
    print(line, flush=True)
    return line
