"""Paper Fig. 4: grouped GEMM throughput scales with group size like the
batch-size scaling of a single GEMM.

On TPU the grouped GEMM is one batched einsum (DESIGN.md §2); here we measure
the same property on the host backend: time-per-group-member falls as the
group grows, and matches batched-GEMM scaling (the foundation of the diagonal
batching speedup)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit


def main(quick: bool = True):
    M = K = N = 256 if quick else 1024
    key = jax.random.PRNGKey(0)
    grouped = jax.jit(lambda x, w: jnp.einsum("gmk,gkn->gmn", x, w))
    single = jax.jit(lambda x, w: x @ w)

    t1 = timeit(single, jax.random.normal(key, (M, K)),
                jax.random.normal(key, (K, N)))
    flops = 2 * M * K * N
    row("gemm_single_g1", t1, f"gflops={flops / t1 / 1e9:.2f}")

    for g in (1, 2, 4, 8, 16):
        x = jax.random.normal(key, (g, M, K))
        w = jax.random.normal(key, (g, K, N))
        tg = timeit(grouped, x, w)
        per = tg / g
        row(f"grouped_gemm_g{g}", per,
            f"gflops={flops / per / 1e9:.2f};rel_eff_vs_g1={t1 / per:.2f}")

        # batched-GEMM equivalent (one weight, batch g) — Fig 4's comparison
        xb = jax.random.normal(key, (g, M, K))
        wb = jax.random.normal(key, (K, N))
        tb = timeit(jax.jit(lambda a, b: a @ b), xb, wb) / g
        row(f"batched_gemm_b{g}", tb, f"gflops={flops / tb / 1e9:.2f}")


if __name__ == "__main__":
    main()
