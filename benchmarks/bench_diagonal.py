"""Schedule comparison with machine-readable output: sequential vs
diagonal-vmap vs diagonal-fused wall-clock per segment count, written to
``BENCH_diagonal.json`` so the perf trajectory is trackable across PRs
(EXPERIMENTS.md §Perf).

The fused rows route the diagonal executor's grouped launch through
models/grouped_blocks.py (auto kernel selection: Pallas on TPU, the jnp
oracles — still one grouped GEMM / batched attention per step — on CPU).
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax

from benchmarks.common import compiled_memory_stats, row, timeit
from repro.configs import ARMTConfig, get_smoke_config
from repro.models import forward_hidden, init_params

SEG = 128


def _config():
    cfg = get_smoke_config("llama-1b-armt")
    return dataclasses.replace(
        cfg, n_layers=8, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, max_position=1 << 16,
        armt=ARMTConfig(segment_len=SEG, num_mem_tokens=8, d_mem=8))


def bench_schedules(quick: bool = True, out_path: str | None = None):
    cfg = _config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    seg_counts = (2, 4, 8, 16) if quick else (4, 16, 64, 256)

    fwd = {
        "sequential": jax.jit(lambda p, t: forward_hidden(
            p, cfg, t, schedule="sequential")[0]),
        "diagonal_vmap": jax.jit(lambda p, t: forward_hidden(
            p, cfg, t, schedule="diagonal", grouped_impl="vmap")[0]),
        "diagonal_fused": jax.jit(lambda p, t: forward_hidden(
            p, cfg, t, schedule="diagonal", grouped_impl="fused")[0]),
    }

    results = []
    for n_seg in seg_counts:
        L = n_seg * SEG
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, L), 8, cfg.vocab)
        rec = {"n_segments": n_seg, "seq_len": L}
        # warmup=2 absorbs compile + first-run allocator noise; median of 5
        # is stable enough to compare across PRs (warmup=1/iters=2 was not)
        for name, fn in fwd.items():
            t = timeit(fn, params, toks, warmup=2, iters=5)
            rec[f"{name}_s"] = t
            rec[f"{name}_tok_s"] = L / t
            # compiled-program memory footprint next to the wall clock
            # (DESIGN.md §15): temp bytes is what the executor's schedule
            # actually holds live, the quantity the streaming-carry work
            # drives flat in n_segments (bench_longctx tracks that curve)
            mem = compiled_memory_stats(fn, params, toks)
            for k in ("argument_bytes", "temp_bytes", "peak_bytes"):
                rec[f"{name}_{k}"] = mem[k]
            row(f"{name}_S{n_seg}", t,
                f"segments={n_seg} {L / t:.0f} tok/s "
                f"temp={mem['temp_bytes']} peak={mem['peak_bytes']}")
        rec["vmap_vs_sequential"] = rec["sequential_s"] / rec["diagonal_vmap_s"]
        rec["fused_vs_vmap"] = rec["diagonal_vmap_s"] / rec["diagonal_fused_s"]
        results.append(rec)

    out_path = out_path or os.environ.get("BENCH_OUT", "BENCH_diagonal.json")
    payload = {
        "bench": "diagonal_schedules",
        "backend": jax.default_backend(),
        "segment_len": SEG,
        "model": {"n_layers": cfg.n_layers, "d_model": cfg.d_model,
                  "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
                  "num_mem_tokens": cfg.armt.num_mem_tokens},
        "schedules": list(fwd),
        "results": results,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    row("bench_diagonal_json", 0.0, out_path)
    return payload


def main(quick: bool = True):
    bench_schedules(quick)


if __name__ == "__main__":
    main(quick=False)
