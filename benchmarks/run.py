# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  Fig. 4   bench_grouped_gemm       grouped GEMM group-size scaling
  Fig. 5   bench_attention          attention group-as-batch scaling
  Tab. 1/5/6/7 bench_inference_scaling  full vs sequential vs diagonal
  Tab. 2   bench_error_accumulation logits drift vs segments (fp32/bf16)
  Tab. 3/4 bench_babilong           needle-QA accuracy + speed
  §Roofline bench_roofline          dry-run artifact aggregation
  §Perf    bench_diagonal           sequential vs diagonal-vmap vs
                                    diagonal-fused -> BENCH_diagonal.json
  §Kernels bench_kernels            per-op autotune sweep + dispatch
                                    decisions -> BENCH_kernels.json
  §Serving bench_serve              continuous-batching + prefix-cache +
                                    session workloads -> BENCH_serve.json
  §Long-context bench_longctx       bounded-memory streaming prefill:
                                    memory curve + 1M-token run ->
                                    BENCH_longctx.json

``QUICK=0 python -m benchmarks.run`` for full sizes.
``python -m benchmarks.run --only serve`` (repeatable, comma-ok) runs a
subset — e.g. just the serve benches in CI, whose JSON is uploaded as a
workflow artifact.
"""
import argparse
import os
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", action="append", default=None,
                    metavar="NAME",
                    help="run only these benches (by short name: "
                         "grouped_gemm, attention, inference_scaling, "
                         "error_accumulation, babilong, roofline, diagonal, "
                         "serve, kernels, longctx); repeatable or "
                         "comma-separated")
    args = ap.parse_args(argv)

    quick = os.environ.get("QUICK", "1") != "0"
    import benchmarks.bench_grouped_gemm as g
    import benchmarks.bench_attention as a
    import benchmarks.bench_inference_scaling as i
    import benchmarks.bench_error_accumulation as e
    import benchmarks.bench_babilong as b
    import benchmarks.bench_roofline as r
    import benchmarks.bench_diagonal as d
    import benchmarks.bench_serve as sv
    import benchmarks.bench_kernels as kn
    import benchmarks.bench_longctx as lc

    by_name = {"grouped_gemm": g, "attention": a, "inference_scaling": i,
               "error_accumulation": e, "babilong": b, "roofline": r,
               "diagonal": d, "serve": sv, "kernels": kn, "longctx": lc}
    mods = list(by_name.values())
    if args.only:
        names = [n.strip() for part in args.only for n in part.split(",")]
        unknown = [n for n in names if n not in by_name]
        if unknown:
            ap.error(f"unknown bench(es) {unknown}; "
                     f"choose from {sorted(by_name)}")
        mods = [by_name[n] for n in names]

    print("name,us_per_call,derived")
    failures = 0
    for mod in mods:
        try:
            mod.main(quick=quick)
        except Exception:
            failures += 1
            print(f"{mod.__name__},0.0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
