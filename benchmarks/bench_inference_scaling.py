"""Paper Tables 1/5/6/7: end-to-end forward time across sequence lengths —
full-attention baseline vs sequential ARMT vs Diagonal Batching ARMT.

CPU-scaled model (the paper's trend, not its absolute numbers): linear-time
ARMT overtakes the quadratic full-attention model as length grows, and the
diagonal schedule beats the sequential one once n_segments is large."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.configs import ARMTConfig, get_smoke_config
from repro.models import forward_hidden, init_params


def bench_model(quick: bool = True):
    cfg = get_smoke_config("llama-1b-armt")
    seg = 128
    cfg = dataclasses.replace(
        cfg, n_layers=8, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, max_position=1 << 16,
        armt=ARMTConfig(segment_len=seg, num_mem_tokens=8, d_mem=8))
    params = init_params(cfg, jax.random.PRNGKey(0))
    lengths = (512, 1024, 2048, 4096) if quick else (1024, 4096, 16384, 65536)

    fwd_full = jax.jit(lambda p, t: forward_hidden(p, cfg, t, mode="full")[0])
    fwd_seq = jax.jit(lambda p, t: forward_hidden(
        p, cfg, t, schedule="sequential")[0])
    fwd_diag = jax.jit(lambda p, t: forward_hidden(
        p, cfg, t, schedule="diagonal")[0])

    for L in lengths:
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, L), 8, cfg.vocab)
        t_full = timeit(fwd_full, params, toks, warmup=1, iters=2)
        t_seq = timeit(fwd_seq, params, toks, warmup=1, iters=2)
        t_diag = timeit(fwd_diag, params, toks, warmup=1, iters=2)
        row(f"full_attn_L{L}", t_full, "")
        row(f"armt_sequential_L{L}", t_seq,
            f"vs_full={t_full / t_seq:.2f}x")
        row(f"armt_diagonal_L{L}", t_diag,
            f"vs_seq={t_seq / t_diag:.2f}x;vs_full={t_full / t_diag:.2f}x;"
            f"segments={L // 128}")


def main(quick: bool = True):
    bench_model(quick)


if __name__ == "__main__":
    main(quick=False)
