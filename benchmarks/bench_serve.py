"""Continuous-batching serving benchmark -> ``BENCH_serve.json``
(EXPERIMENTS.md §Serving, §Prefix-cache).

For each concurrency level (number of decode slots) the same request set —
heterogeneous prompt lengths, all queued at t=0 — is pushed through
``ServeEngine.serve``; we record aggregate decode throughput (tok/s),
per-request time-to-first-token and tokens/sec (read off the stream's own
``StreamEvent`` metrics — host-clock, chunk-granular by design), and
per-request completion latency. A one-request-at-a-time `generate` pass
over the identical set is the no-continuous-batching baseline. A warmup
pass absorbs compilation so the numbers measure the steady state.

Every scheduler record also carries inter-token-latency percentiles
(``itl_s_p50``/``itl_s_p99``) and ``admission_stall_s`` — the max decode
gap whose interval overlaps an admission window, i.e. the head-of-line
stall an admission inflicts on already-decoding slots. Both are derived
by the serve stack's OWN trace recorder (serve/telemetry.py, DESIGN.md
§13) from the per-chunk emit stamps and admission spans — one source of
truth shared with ``--trace-out`` timelines, not a bench-local rescan of
``t_emit`` gaps (tests/test_telemetry.py asserts the derivations agree
with the pre-PR-7 reference implementations). A ``telemetry`` payload
section records the engine's compile counts, the process registry
(XLA backend compiles, sharding fallbacks), and a paired telemetry-on vs
telemetry-off decode-throughput overhead ratio (acceptance floor 0.98);
the pooled burst run's full Chrome trace is exported to
``BENCH_SERVE_TRACE`` (default ``BENCH_serve_trace.json``) and
schema-validated.

A ``mixed_workload`` scenario (DESIGN.md §11) drops long-prompt admissions
into a steadily decoding pool and runs the SAME request set in both
admission modes — blocking (``prefill_groups_per_chunk=0``, the legacy
path) and interleaved (the default resumable-pipeline path) — recording
the stall reduction at equal total throughput.

A ``burst_admission`` scenario (DESIGN.md §12) pushes a 4-prompt burst of
long admissions through the backlog alongside steady decoders and compares
blocking vs single-carry interleaved (PR 5, ``max_concurrent=1``) vs the
pooled admission pool (``max_concurrent=4``, round-robin) — the headline
is the summed burst queue wait (``StreamEvent.queue_wait_s``, stamped
``t_admit - t_submit`` by the scheduler) at a paired steady-decode
throughput ratio ≥ 0.95. Every scheduler record now also carries
``queue_wait_s_mean``/``queue_wait_s_max``/``concurrent_admissions_max``.

Two state-store workloads (serve/state_store.py):
  * shared_prefix — N requests sharing a multi-segment system prompt;
    cold admission (PR 2 path: full diagonal prefill per request) vs a
    prefix-cached engine where admissions after the first transplant the
    boundary snapshot and prefill only the uncached tail. The metric is
    admission time = ``GenerationResult.ttft_s``.
  * multi_turn — a T-turn conversation; re-prefill-the-history baseline vs
    session-store resume (O(new turn) admission).

``--mesh data=2,model=4`` (launch/mesh.py spec syntax) adds a mesh-native
pass (DESIGN.md §10): the same request set through a sharded engine,
recorded as ``mesh_results`` with its mesh shape inline. The main
``results`` trajectory always stays single-device so it remains comparable
across PRs; ``device_count`` is recorded top-level for hardware
provenance. On CPU a mesh needs
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before Python
starts.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import paired_median, row
from repro.configs import ARMTConfig, get_smoke_config
from repro.models import init_params
from repro.serve import (MetricsRegistry, PrefixCache, Request, ServeEngine,
                         SessionStore, Telemetry, default_registry,
                         validate_chrome_trace)

SEG = 32


def _config():
    cfg = get_smoke_config("llama-1b-armt")
    return dataclasses.replace(
        cfg, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, max_position=1 << 16,
        armt=ARMTConfig(segment_len=SEG, num_mem_tokens=8, d_mem=8))


def _requests(cfg, n, max_new, seed=0):
    rng = np.random.default_rng(seed)
    # two prompt-length buckets (bounded compile shapes) at different
    # segment phases
    lens = [2 * SEG if i % 2 == 0 else 2 * SEG + SEG // 2 for i in range(n)]
    return [Request(req_id=f"r{i}",
                    prompt=rng.integers(8, cfg.vocab, (L,)).astype(np.int32),
                    max_new=max_new)
            for i, L in enumerate(lens)]


def _drive(eng, reqs, n_slots, chunk, *, groups_per_chunk=4, fused=False,
           max_concurrent=None, fairness="round_robin", max_queue=None,
           detail=False, trace_path=None, embed_metrics=False):
    # per-request timings come from the stream's own metrics (StreamEvent
    # ttft_s / tok_s / queue_wait_s); ITL percentiles and admission stall
    # come from the trace recorder's emit stamps and admission spans —
    # the same timeline --trace-out exports. The scheduler is built
    # directly so it picks up the per-run Telemetry swapped onto the
    # engine. max_queue switches to the push model (backlog drained at
    # t=0), which is what makes queue_wait_s measure real head-of-line
    # waiting instead of pull latency.
    from repro.serve.scheduler import ContinuousScheduler
    # compiled-prefill memory columns (DESIGN.md §15) for the workload's
    # largest admission: AOT memory_analysis of the stepper this record's
    # admissions actually run. Cached per (engine, signature), so repeated
    # drives of the same workload pay the extra compile once.
    n_seg = max(1, max(int(np.asarray(r.prompt).shape[0]) for r in reqs)
                // eng.seg_len)
    mem = eng.prefill_memory_stats(
        n_seg, n_groups=groups_per_chunk if groups_per_chunk > 0 else 4)
    tel = Telemetry(trace=True, registry=MetricsRegistry())
    prev_tel, eng.telemetry = eng.telemetry, tel
    sched = ContinuousScheduler(eng, n_slots=n_slots, chunk=chunk,
                                max_queue=max_queue,
                                prefill_groups_per_chunk=groups_per_chunk,
                                fused_admission=fused,
                                max_concurrent_admissions=max_concurrent,
                                admission_fairness=fairness)
    t0 = time.perf_counter()
    ttft, tok_s, done_at, n_tok = {}, {}, {}, 0
    qwait, conc = {}, {}
    try:
        for ev in sched.run(iter(reqs)):
            n_tok += 1
            if ev.done:
                ttft[ev.req_id] = ev.ttft_s
                tok_s[ev.req_id] = ev.tok_s
                done_at[ev.req_id] = time.perf_counter() - t0
                qwait[ev.req_id] = ev.queue_wait_s
                conc[ev.req_id] = ev.concurrent_admissions
    finally:
        eng.telemetry = prev_tel
    wall = time.perf_counter() - t0
    itl_p50, itl_p99 = tel.trace.itl_percentiles()
    rec = {
        "wall_s": wall,
        "throughput_tok_s": n_tok / wall,
        "ttft_s_mean": float(np.mean(list(ttft.values()))),
        "ttft_s_max": float(np.max(list(ttft.values()))),
        "request_tok_s_mean": float(np.mean(list(tok_s.values()))),
        "latency_s_mean": float(np.mean(list(done_at.values()))),
        "latency_s_max": float(np.max(list(done_at.values()))),
        "itl_s_p50": itl_p50,
        "itl_s_p99": itl_p99,
        "admission_stall_s": tel.trace.admission_stall_s(),
        "queue_wait_s_mean": float(np.mean(list(qwait.values()))),
        "queue_wait_s_max": float(np.max(list(qwait.values()))),
        "concurrent_admissions_max": int(max(conc.values())),
        "prefill_n_segments": n_seg,
        "prefill_argument_bytes": mem["argument_bytes"],
        "prefill_temp_bytes": mem["temp_bytes"],
        "prefill_peak_bytes": mem["peak_bytes"],
    }
    if detail:
        rec["per_request"] = {
            rid: {"ttft_s": ttft[rid], "tok_s": tok_s[rid],
                  "queue_wait_s": qwait[rid],
                  "concurrent_admissions": conc[rid]}
            for rid in ttft}
    if embed_metrics:
        rec["metrics"] = tel.registry.snapshot()
    if trace_path is not None:
        trace = tel.trace.chrome_trace()
        errors = validate_chrome_trace(trace)
        with open(trace_path, "w") as f:
            json.dump(trace, f)
        rec["trace_artifact"] = {
            "path": trace_path,
            "n_events": len(trace["traceEvents"]),
            "valid": not errors,
            "errors": errors,
        }
    return rec


def _bench_shared_prefix(cfg, params, quick: bool):
    """Admission time (TTFT) for requests sharing a system prompt: cold
    (every admission re-prefills the shared segments — the PR 2 path) vs
    prefix-cached (admissions after the first transplant the snapshot)."""
    n_sys_seg = 4 if quick else 8
    n_req = 6 if quick else 12
    max_new = 8
    tail = SEG // 2
    rng = np.random.default_rng(3)
    sys_prompt = rng.integers(8, cfg.vocab, (n_sys_seg * SEG,)).astype(np.int32)
    prompts = [np.concatenate([sys_prompt,
                               rng.integers(8, cfg.vocab, (tail,)).astype(np.int32)])
               for _ in range(n_req)]
    max_len = (n_sys_seg + 2) * SEG + max_new

    def run(engine):
        # warmup absorbs compiles (same shapes, different tokens/prefix)
        warm_p = rng.integers(8, cfg.vocab,
                              (n_sys_seg * SEG + tail,)).astype(np.int32)
        engine.generate(warm_p[None], max_new)
        ttfts, cached = [], []
        for p in prompts:
            r = engine.generate(p[None], max_new)
            ttfts.append(r.ttft_s)
            cached.append(r.cached_segments)
        return ttfts, cached

    cold = ServeEngine(params, cfg, serve_mode="armt", max_len=max_len)
    ttft_cold, _ = run(cold)
    cache = PrefixCache(SEG, max_bytes=64 << 20)
    warm = ServeEngine(params, cfg, serve_mode="armt", max_len=max_len,
                       prefix_cache=cache)
    ttft_warm, cached = run(warm)
    # first request is the cold fill; hits are the rest
    hit_ttft = ttft_warm[1:]
    rec = {
        "n_requests": n_req, "system_prompt_segments": n_sys_seg,
        "tail_tokens": tail, "max_new": max_new,
        "ttft_s_cold_mean": float(np.mean(ttft_cold)),
        "ttft_s_first_fill": ttft_warm[0],
        "ttft_s_hit_mean": float(np.mean(hit_ttft)),
        "hit_cached_segments": cached[1:],
        "ttft_reduction_x": float(np.mean(ttft_cold) / np.mean(hit_ttft)),
        "cache_stats": cache.stats.as_dict(),
    }
    row("serve_shared_prefix", rec["ttft_s_hit_mean"],
        f"ttft cold={rec['ttft_s_cold_mean']:.3f}s "
        f"hit={rec['ttft_s_hit_mean']:.3f}s "
        f"({rec['ttft_reduction_x']:.1f}x)")
    return rec


def _bench_multi_turn(cfg, params, quick: bool):
    """T-turn chat: session-store resume vs re-prefilling the full history
    each turn. Outputs are asserted token-identical between the two."""
    n_turns = 3 if quick else 5
    turn_len = SEG
    max_new = 8
    max_len = ((n_turns + 1) * (turn_len + max_new) // SEG + 2) * SEG
    rng = np.random.default_rng(4)
    turns = [rng.integers(8, cfg.vocab, (turn_len,)).astype(np.int32)
             for _ in range(n_turns)]

    store = SessionStore(max_bytes=128 << 20)
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=max_len,
                      session_store=store)
    # warmup: same turn shapes under a throwaway session
    for t in turns:
        eng.generate(rng.integers(8, cfg.vocab, (turn_len,))[None].astype(np.int32),
                     max_new, session_id="warm")

    ttft_resume, ttft_full, outs = [], [], []
    for i, t in enumerate(turns):
        r = eng.generate(t[None], max_new, session_id="chat")
        ttft_resume.append(r.ttft_s)
        outs.append(r.tokens[0])
    history = np.empty(0, np.int32)
    for i, t in enumerate(turns):
        prompt = np.concatenate([history, t])
        r = eng.generate(prompt[None], max_new)    # no session: full prefill
        ttft_full.append(r.ttft_s)
        assert (r.tokens[0] == outs[i]).all(), \
            f"turn {i}: session resume diverged from full-history prefill"
        history = np.concatenate([prompt, r.tokens[0]]).astype(np.int32)
    rec = {
        "n_turns": n_turns, "turn_tokens": turn_len, "max_new": max_new,
        "ttft_s_resume": ttft_resume, "ttft_s_full_prefill": ttft_full,
        "ttft_s_resume_mean_after_first": float(np.mean(ttft_resume[1:])),
        "ttft_s_full_mean_after_first": float(np.mean(ttft_full[1:])),
        "ttft_reduction_x_last_turn": ttft_full[-1] / ttft_resume[-1],
        "final_history_tokens": int(history.shape[0]),
    }
    row("serve_multi_turn", rec["ttft_s_resume_mean_after_first"],
        f"resume={rec['ttft_s_resume_mean_after_first']:.3f}s "
        f"full={rec['ttft_s_full_mean_after_first']:.3f}s "
        f"(turn {n_turns}: {rec['ttft_reduction_x_last_turn']:.1f}x)")
    return rec


def _bench_mixed_workload(cfg, params, quick: bool):
    """Long-prompt admissions landing mid-steady-decode (DESIGN.md §11,
    EXPERIMENTS.md §Interleaved-prefill): a pool of steady decoders is
    running when long-prompt requests arrive; blocking admission
    (prefill_groups_per_chunk=0, the PR 2 path) freezes every stream for
    the whole prefill, interleaved admission (the default) advances the
    prefill a few diagonal groups per chunk. Same request set, both modes;
    the headline is ``admission_stall_s`` (max decode gap overlapping an
    admission) at equal total throughput."""
    # its own engine/model: a slightly bigger stack and segment length than
    # the throughput trajectory's smoke config, so per-group prefill
    # compute dominates per-dispatch overhead and the stall numbers measure
    # scheduling, not jax dispatch latency
    seg_mix = 64
    mix_cfg = dataclasses.replace(
        cfg, n_layers=6, d_model=128, n_heads=8, n_kv_heads=8, d_head=16,
        d_ff=384,
        armt=ARMTConfig(segment_len=seg_mix, num_mem_tokens=8, d_mem=8))
    mix_params = init_params(mix_cfg, jax.random.PRNGKey(2))
    # 32 segments = one pow2 bucket, so the blocking baseline's stall is
    # the whole prefill (a multi-stage prompt would cap it at the largest
    # stage); the steady phase is long enough that both admissions land
    # and finish while the other slots are mid-decode
    n_long_seg = 32 if quick else 64
    steady_new = 384 if quick else 512
    short_new = 12
    n_slots, chunk = 4, 8
    reps = 3                     # best-of-3 for stall/wall/throughput,
    #                              median elsewhere (see below) — host-clock
    #                              numbers on shared CI boxes are noisy, so
    #                              one record may mix values from different
    #                              runs (throughput != n_tok/wall_s)
    eng = ServeEngine(mix_params, mix_cfg, serve_mode="armt",
                      max_len=2 * seg_mix + steady_new)

    def reqs():
        rng = np.random.default_rng(11)
        steady = [Request(f"s{i}",
                          rng.integers(8, mix_cfg.vocab,
                                       (2 * seg_mix,)).astype(np.int32),
                          steady_new if i < n_slots - 1 else short_new)
                  for i in range(n_slots)]
        # the short steady request frees its slot early, so the long
        # admissions land while the other slots are mid-decode
        longs = [Request(f"L{i}",
                         rng.integers(8, mix_cfg.vocab,
                                      (n_long_seg * seg_mix,)).astype(np.int32),
                         short_new)
                 for i in range(2)]
        return steady + longs

    # four admission modes over the SAME request set:
    #   legacy_blocking (k=0)  — the PR 2 path (eager _prefill per
    #     admission; at smoke scale its wall is dominated by per-admission
    #     retracing, recorded for coverage, not the headline baseline);
    #   blocking (k=-1)        — whole diagonal stage per advance through
    #     the jitted stepper: blocking head-of-line semantics at equal
    #     total work, the fair baseline for the stall claim;
    #   interleaved (k=4)      — the default resumable pipeline;
    #   fused (k=4)            — admission groups inside the decode
    #     chunk's launch (one dispatch per interval).
    rec = {"n_slots": n_slots, "chunk": chunk, "segment_len": seg_mix,
           "long_prompt_segments": n_long_seg, "steady_max_new": steady_new,
           "model": {"n_layers": mix_cfg.n_layers,
                     "d_model": mix_cfg.d_model, "d_ff": mix_cfg.d_ff}}
    modes = (("legacy_blocking", 0, False), ("blocking", -1, False),
             ("interleaved", 4, False), ("fused", 4, True))
    for name, k, fused in modes:                                   # warmup
        _drive(eng, reqs(), n_slots, chunk, groups_per_chunk=k, fused=fused)
    # round-robin the repetitions across modes (A/B/C, A/B/C, ...) so a
    # drifting host load hits every mode's samples equally instead of
    # biasing whichever mode happened to run during a slow phase
    runs = {name: [] for name, _, _ in modes}
    for rep in range(reps):
        for name, k, fused in modes:
            if name == "legacy_blocking" and rep > 0:
                continue                     # coverage row: one rep is enough
            runs[name].append(_drive(eng, reqs(), n_slots, chunk,
                                     groups_per_chunk=k, fused=fused))
    for name, k, fused in modes:
        # best-of-N per metric: host noise strictly *inflates* a max-gap
        # (admission_stall is the max inter-event gap) and strictly
        # *deflates* throughput, so min/max isolate the intrinsic
        # scheduling behavior from box hiccups; everything else is median
        best = {"admission_stall_s": min, "wall_s": min,
                "throughput_tok_s": max}
        # memory columns may be None on backends without memory_analysis
        rec[name] = {kk: (None if runs[name][0][kk] is None else float(
            best.get(kk, np.median)([r[kk] for r in runs[name]])))
            for kk in runs[name][0]}
        rec[name]["reps"] = len(runs[name])
        rec[name]["prefill_groups_per_chunk"] = k
        rec[name]["fused_admission"] = fused
    # the headline ratios pair each rep's interleaved/fused sample with the
    # *temporally adjacent* blocking sample of the same round-robin round
    # and take the median of the per-rep ratios — the host (a cgroup-shared
    # box) drifts 2-3x over minutes, which cancels within a round but not
    # across per-mode aggregates
    def paired(metric, num, den):
        return paired_median(runs, metric, num, den)

    rec["stall_reduction_x"] = paired("admission_stall_s",
                                      "blocking", "interleaved")
    rec["stall_reduction_fused_x"] = paired("admission_stall_s",
                                            "blocking", "fused")
    rec["throughput_ratio"] = paired("throughput_tok_s",
                                     "interleaved", "blocking")
    rec["throughput_ratio_fused"] = paired("throughput_tok_s",
                                           "fused", "blocking")
    blk, itl = rec["blocking"], rec["interleaved"]
    row("serve_mixed_workload", itl["admission_stall_s"],
        f"stall blocking={blk['admission_stall_s']:.3f}s "
        f"interleaved={itl['admission_stall_s']:.3f}s "
        f"({rec['stall_reduction_x']:.1f}x, "
        f"fused {rec['stall_reduction_fused_x']:.1f}x) "
        f"tput ratio={rec['throughput_ratio']:.2f}")
    return rec


def _bench_burst_admission(cfg, params, quick: bool):
    """A burst of long prompts landing at t=0 on a pool with free slots
    (DESIGN.md §12, EXPERIMENTS.md §Concurrent-admissions): two steady
    decoders plus four long admissions, pushed through the backlog (push
    model) so ``queue_wait_s`` measures real head-of-line waiting. Three
    admission modes over the SAME request set:

      blocking (k=-1)        — whole diagonal stage per advance, one
        admission at a time (head-of-line at equal total work);
      interleaved_n1 (k=4, max_concurrent=1) — the PR 5 single-carry
        resumable pipeline: decode keeps flowing but the burst still
        serializes behind ONE suspended carry;
      pooled_n4 (k=4, max_concurrent=4, round_robin) — the §12 admission
        pool: every burst member's carry advances each round, same-
        signature carries batched into one pooled launch.

    The headline is ``burst_wait_s`` — the summed queue wait of the four
    burst requests (time between submission and their admission actually
    starting) — which the pool attacks directly: waits collapse from
    whole-admissions-ahead to pool-capacity scheduling. Decode throughput
    of the steady requests is recorded alongside (paired ratio vs
    interleaved_n1; acceptance floor 0.95)."""
    seg_b = 64
    b_cfg = dataclasses.replace(
        cfg, n_layers=6, d_model=128, n_heads=8, n_kv_heads=8, d_head=16,
        d_ff=384,
        armt=ARMTConfig(segment_len=seg_b, num_mem_tokens=8, d_mem=8))
    b_params = init_params(b_cfg, jax.random.PRNGKey(5))
    n_long_seg = 8 if quick else 16
    steady_new = 192 if quick else 320
    burst_new = 12
    n_slots, chunk = 6, 8
    reps = 3
    eng = ServeEngine(b_params, b_cfg, serve_mode="armt",
                      max_len=n_long_seg * seg_b + steady_new)

    def reqs():
        rng = np.random.default_rng(12)
        steady = [Request(f"s{i}",
                          rng.integers(8, b_cfg.vocab,
                                       (2 * seg_b,)).astype(np.int32),
                          steady_new)
                  for i in range(2)]
        longs = [Request(f"L{i}",
                         rng.integers(8, b_cfg.vocab,
                                      (n_long_seg * seg_b,)).astype(np.int32),
                         burst_new)
                 for i in range(4)]
        return steady + longs

    modes = (("blocking", dict(groups_per_chunk=-1, max_concurrent=1)),
             ("interleaved_n1", dict(groups_per_chunk=4, max_concurrent=1)),
             ("pooled_n4", dict(groups_per_chunk=4, max_concurrent=4)))
    rec = {"n_slots": n_slots, "chunk": chunk, "segment_len": seg_b,
           "burst_prompts": 4, "burst_prompt_segments": n_long_seg,
           "steady_decoders": 2, "steady_max_new": steady_new,
           "model": {"n_layers": b_cfg.n_layers, "d_model": b_cfg.d_model,
                     "d_ff": b_cfg.d_ff}}
    for name, kw in modes:                                         # warmup
        _drive(eng, reqs(), n_slots, chunk, max_queue=8, detail=True, **kw)
    # round-robin reps across modes so host drift cancels within a round
    # (same rationale as the mixed_workload pairing)
    # the final pooled run's full Chrome trace is the bench's observability
    # artifact: chunks, admission rounds, flushes and idle-drain rounds of
    # the burst scenario, schema-validated before the payload records it
    trace_out = os.environ.get("BENCH_SERVE_TRACE", "BENCH_serve_trace.json")
    trace_info = None
    runs = {name: [] for name, _ in modes}
    for rep in range(reps):
        for name, kw in modes:
            last_pooled = name == "pooled_n4" and rep == reps - 1
            r = _drive(eng, reqs(), n_slots, chunk, max_queue=8,
                       detail=True,
                       trace_path=trace_out if last_pooled else None, **kw)
            if last_pooled:
                trace_info = r.pop("trace_artifact")
            per = r.pop("per_request")
            r["burst_wait_s"] = float(
                sum(per[f"L{i}"]["queue_wait_s"] for i in range(4)))
            r["burst_ttft_s_sum"] = float(
                sum(per[f"L{i}"]["ttft_s"] for i in range(4)))
            r["steady_tok_s"] = float(
                np.mean([per[f"s{i}"]["tok_s"] for i in range(2)]))
            runs[name].append(r)
    for name, kw in modes:
        best = {"burst_wait_s": min, "wall_s": min,
                "throughput_tok_s": max, "steady_tok_s": max}
        rec[name] = {kk: (None if runs[name][0][kk] is None else float(
            best.get(kk, np.median)([r[kk] for r in runs[name]])))
            for kk in runs[name][0]}
        rec[name]["reps"] = reps
        rec[name].update({k: v for k, v in kw.items()})

    def paired(metric, num, den):
        return paired_median(runs, metric, num, den)

    rec["burst_wait_reduction_x"] = paired("burst_wait_s",
                                           "interleaved_n1", "pooled_n4")
    rec["burst_wait_reduction_vs_blocking_x"] = paired(
        "burst_wait_s", "blocking", "pooled_n4")
    rec["steady_tok_s_ratio"] = paired("steady_tok_s",
                                       "pooled_n4", "interleaved_n1")
    rec["trace_artifact"] = trace_info
    n1, n4 = rec["interleaved_n1"], rec["pooled_n4"]
    row("serve_burst_admission", n4["burst_wait_s"],
        f"burst wait n1={n1['burst_wait_s']:.3f}s "
        f"pooled={n4['burst_wait_s']:.3f}s "
        f"({rec['burst_wait_reduction_x']:.1f}x, vs blocking "
        f"{rec['burst_wait_reduction_vs_blocking_x']:.1f}x) "
        f"steady tok/s ratio={rec['steady_tok_s_ratio']:.2f} "
        f"conc max={n4['concurrent_admissions_max']}")
    return rec


def _bench_telemetry_overhead(cfg, params, quick: bool):
    """Paired decode-throughput cost of the telemetry layer (DESIGN.md
    §13): the SAME steady-decode workload driven with full telemetry
    (trace recorder + metrics registry) vs ``Telemetry.disabled()``. The
    recorder is host-side and piggybacks on the scheduler's once-per-chunk
    host transfer — zero extra device syncs — so the paired median ratio
    should be ~1.0 (acceptance floor 0.98, EXPERIMENTS.md
    §Observability)."""
    from repro.serve.scheduler import ContinuousScheduler
    max_new = 96 if quick else 256
    n_slots, chunk = 4, 8
    reps = 5          # drives are ~100ms each; extra reps are cheap and the
    #                   ratio is a ~2% effect under >10% host drift
    eng = ServeEngine(params, cfg, serve_mode="armt",
                      max_len=4 * SEG + max_new)

    def drive(tel):
        prev, eng.telemetry = eng.telemetry, tel
        sched = ContinuousScheduler(eng, n_slots=n_slots, chunk=chunk)
        t0 = time.perf_counter()
        n_tok = 0
        try:
            for _ in sched.run(iter(_requests(cfg, n_slots, max_new,
                                              seed=7))):
                n_tok += 1
        finally:
            eng.telemetry = prev
        wall = time.perf_counter() - t0
        return {"wall_s": wall, "throughput_tok_s": n_tok / wall}

    modes = (("off", Telemetry.disabled),
             ("on", lambda: Telemetry(trace=True,
                                      registry=MetricsRegistry())))
    for _, mk in modes:                                            # warmup
        drive(mk())
    # round-robin off/on within each rep so host drift cancels in the pair
    runs = {name: [] for name, _ in modes}
    for _ in range(reps):
        for name, mk in modes:
            runs[name].append(drive(mk()))
    rec = {"n_slots": n_slots, "chunk": chunk, "max_new": max_new,
           "reps": reps}
    for name, _ in modes:
        rec[name] = {
            "wall_s": float(min(r["wall_s"] for r in runs[name])),
            "throughput_tok_s": float(max(r["throughput_tok_s"]
                                          for r in runs[name]))}
    rec["throughput_ratio_on_off"] = paired_median(
        runs, "throughput_tok_s", "on", "off")
    row("serve_telemetry_overhead", rec["throughput_ratio_on_off"],
        f"on/off tok/s ratio={rec['throughput_ratio_on_off']:.3f} "
        f"(floor 0.98)")
    return rec


def bench_serve(quick: bool = True, out_path: str | None = None,
                mesh_spec: str | None = None):
    cfg = _config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_new = 32 if quick else 128
    chunk = 8
    slot_counts = (1, 2, 4) if quick else (1, 2, 4, 8)
    n_req = 2 * max(slot_counts)

    mesh = None
    if mesh_spec:
        from repro.launch.mesh import parse_mesh
        mesh = parse_mesh(mesh_spec)
        row("serve_mesh", 0.0, f"{dict(mesh.shape)}")

    eng = ServeEngine(params, cfg, serve_mode="armt",
                      max_len=4 * SEG + max_new)
    reqs = _requests(cfg, n_req, max_new)

    def warm(engine, n_slots):
        # compile prefill shapes and trace the shared packed step / admit
        # fns for this slot count, so the timed pass measures steady state
        for _ in engine.serve(_requests(cfg, max(2, n_slots), chunk, seed=1),
                              n_slots=n_slots, chunk=chunk):
            pass

    # no-continuous-batching baseline: one request at a time
    eng.generate(np.asarray(reqs[0].prompt)[None], max_new)       # warm
    eng.generate(np.asarray(reqs[1].prompt)[None], max_new)
    t0 = time.perf_counter()
    for r in reqs:
        eng.generate(np.asarray(r.prompt)[None], max_new)
    base_wall = time.perf_counter() - t0
    baseline_tok_s = n_req * max_new / base_wall
    row("serve_one_by_one", base_wall, f"{baseline_tok_s:.1f} tok/s")

    results = []
    for n_slots in slot_counts:
        warm(eng, n_slots)
        rec = {"n_slots": n_slots, "n_requests": n_req, "max_new": max_new,
               "chunk": chunk}
        # the largest slot count carries its full per-run metrics snapshot
        # (pool occupancy, queue depth, flush counters, ...) so the JSON
        # artifact shows the registry's view without bloating every record
        rec.update(_drive(eng, reqs, n_slots, chunk,
                          embed_metrics=n_slots == max(slot_counts)))
        rec["speedup_vs_one_by_one"] = rec["throughput_tok_s"] / baseline_tok_s
        results.append(rec)
        row(f"serve_slots{n_slots}", rec["wall_s"],
            f"{rec['throughput_tok_s']:.1f} tok/s "
            f"ttft={rec['ttft_s_mean']:.3f}s")

    # mesh-native pass (DESIGN.md §10): same request set through a sharded
    # engine, its own record annotated with the mesh shape — the single-
    # device trajectory above stays comparable across hardware, and this
    # section tracks what the mesh costs/buys on the same workload
    mesh_results = None
    if mesh is not None:
        eng_m = ServeEngine(params, cfg, serve_mode="armt",
                            max_len=4 * SEG + max_new, mesh=mesh)
        n_slots = max(slot_counts)
        warm(eng_m, n_slots)
        rec = {"mesh": dict(mesh.shape), "device_count": jax.device_count(),
               "n_slots": n_slots, "n_requests": n_req, "max_new": max_new,
               "chunk": chunk}
        rec.update(_drive(eng_m, reqs, n_slots, chunk))
        mesh_results = rec
        row(f"serve_mesh_slots{n_slots}", rec["wall_s"],
            f"{rec['throughput_tok_s']:.1f} tok/s on {dict(mesh.shape)}")

    # store workloads stay mesh-less so their TTFT trajectories remain
    # comparable across PRs; sharded store exactness is covered by
    # tests/test_serve_sharded.py
    shared_prefix = _bench_shared_prefix(cfg, params, quick)
    multi_turn = _bench_multi_turn(cfg, params, quick)
    # interleaved vs blocking admission under steady decode — runs BOTH
    # modes so the legacy blocking path stays covered in CI
    mixed_workload = _bench_mixed_workload(cfg, params, quick)
    # pooled concurrent admissions vs the single-carry interleaved mode
    # under a 4-prompt burst (DESIGN.md §12)
    burst_admission = _bench_burst_admission(cfg, params, quick)
    # telemetry-on vs telemetry-off paired decode throughput (DESIGN.md
    # §13 zero-sync argument, measured)
    telemetry_overhead = _bench_telemetry_overhead(cfg, params, quick)

    # own env var — sharing BENCH_OUT with bench_diagonal would make the two
    # benches overwrite each other's artifact under benchmarks.run
    out_path = out_path or os.environ.get("BENCH_SERVE_OUT",
                                          "BENCH_serve.json")
    payload = {
        "bench": "serve_continuous_batching",
        "backend": jax.default_backend(),
        # hardware provenance; the mesh shape lives inside mesh_results —
        # the only record actually produced on a mesh (results/shared_prefix/
        # multi_turn are always single-device for cross-PR comparability)
        "device_count": jax.device_count(),
        "segment_len": SEG,
        "model": {"n_layers": cfg.n_layers, "d_model": cfg.d_model,
                  "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
                  "num_mem_tokens": cfg.armt.num_mem_tokens},
        "baseline_one_by_one_tok_s": baseline_tok_s,
        "results": results,
        "mesh_results": mesh_results,
        "shared_prefix": shared_prefix,
        "multi_turn": multi_turn,
        "mixed_workload": mixed_workload,
        "burst_admission": burst_admission,
        # observability section (ISSUE 8 / DESIGN.md §13): engine jit-cache
        # sizes (the pow2-bucket "O(log) compiles" claim in numbers), the
        # process-wide registry (XLA backend-compile events, sharding
        # fallbacks) and the measured telemetry overhead ratio
        "telemetry": {
            "engine_compile_counts": eng.compile_counts(),
            "registry": default_registry().snapshot(),
            "overhead": telemetry_overhead,
        },
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    row("bench_serve_json", 0.0, out_path)
    return payload


def main(quick: bool = True):
    # benchmarks.run entry point: mesh (if any) comes from BENCH_SERVE_MESH
    # so the harness signature stays uniform across benches
    bench_serve(quick, mesh_spec=os.environ.get("BENCH_SERVE_MESH"))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--mesh", default=None, metavar="AXES",
                    help="mesh-native engines, e.g. 'data=2,model=4' "
                         "(launch/mesh.py syntax); on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N before "
                         "Python starts")
    args = ap.parse_args()
    bench_serve(quick=args.quick, mesh_spec=args.mesh)
