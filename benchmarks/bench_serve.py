"""Continuous-batching serving benchmark -> ``BENCH_serve.json``
(EXPERIMENTS.md §Serving).

For each concurrency level (number of decode slots) the same request set —
heterogeneous prompt lengths, all queued at t=0 — is pushed through
``ServeEngine.serve``; we record aggregate decode throughput (tok/s),
per-request time-to-first-token (first streamed event; chunk-granular by
design), and per-request completion latency. A one-request-at-a-time
`generate` pass over the identical set is the no-continuous-batching
baseline. A warmup pass absorbs compilation so the numbers measure the
steady state.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import row
from repro.configs import ARMTConfig, get_smoke_config
from repro.models import init_params
from repro.serve import Request, ServeEngine

SEG = 32


def _config():
    cfg = get_smoke_config("llama-1b-armt")
    return dataclasses.replace(
        cfg, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, max_position=1 << 16,
        armt=ARMTConfig(segment_len=SEG, num_mem_tokens=8, d_mem=8))


def _requests(cfg, n, max_new, seed=0):
    rng = np.random.default_rng(seed)
    # two prompt-length buckets (bounded compile shapes) at different
    # segment phases
    lens = [2 * SEG if i % 2 == 0 else 2 * SEG + SEG // 2 for i in range(n)]
    return [Request(req_id=f"r{i}",
                    prompt=rng.integers(8, cfg.vocab, (L,)).astype(np.int32),
                    max_new=max_new)
            for i, L in enumerate(lens)]


def _drive(eng, reqs, n_slots, chunk):
    t0 = time.perf_counter()
    ttft, done_at, n_tok = {}, {}, 0
    for ev in eng.serve(reqs, n_slots=n_slots, chunk=chunk):
        now = time.perf_counter() - t0
        n_tok += 1
        ttft.setdefault(ev.req_id, now)
        if ev.done:
            done_at[ev.req_id] = now
    wall = time.perf_counter() - t0
    return {
        "wall_s": wall,
        "throughput_tok_s": n_tok / wall,
        "ttft_s_mean": float(np.mean(list(ttft.values()))),
        "ttft_s_max": float(np.max(list(ttft.values()))),
        "latency_s_mean": float(np.mean(list(done_at.values()))),
        "latency_s_max": float(np.max(list(done_at.values()))),
    }


def bench_serve(quick: bool = True, out_path: str | None = None):
    cfg = _config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_new = 32 if quick else 128
    chunk = 8
    slot_counts = (1, 2, 4) if quick else (1, 2, 4, 8)
    n_req = 2 * max(slot_counts)

    eng = ServeEngine(params, cfg, serve_mode="armt",
                      max_len=4 * SEG + max_new)
    reqs = _requests(cfg, n_req, max_new)

    def warm(n_slots):
        # compile prefill shapes and trace the shared packed step / admit
        # fns for this slot count, so the timed pass measures steady state
        for _ in eng.serve(_requests(cfg, max(2, n_slots), chunk, seed=1),
                           n_slots=n_slots, chunk=chunk):
            pass

    # no-continuous-batching baseline: one request at a time
    eng.generate(np.asarray(reqs[0].prompt)[None], max_new)       # warm
    eng.generate(np.asarray(reqs[1].prompt)[None], max_new)
    t0 = time.perf_counter()
    for r in reqs:
        eng.generate(np.asarray(r.prompt)[None], max_new)
    base_wall = time.perf_counter() - t0
    baseline_tok_s = n_req * max_new / base_wall
    row("serve_one_by_one", base_wall, f"{baseline_tok_s:.1f} tok/s")

    results = []
    for n_slots in slot_counts:
        warm(n_slots)
        rec = {"n_slots": n_slots, "n_requests": n_req, "max_new": max_new,
               "chunk": chunk}
        rec.update(_drive(eng, reqs, n_slots, chunk))
        rec["speedup_vs_one_by_one"] = rec["throughput_tok_s"] / baseline_tok_s
        results.append(rec)
        row(f"serve_slots{n_slots}", rec["wall_s"],
            f"{rec['throughput_tok_s']:.1f} tok/s "
            f"ttft={rec['ttft_s_mean']:.3f}s")

    # own env var — sharing BENCH_OUT with bench_diagonal would make the two
    # benches overwrite each other's artifact under benchmarks.run
    out_path = out_path or os.environ.get("BENCH_SERVE_OUT",
                                          "BENCH_serve.json")
    payload = {
        "bench": "serve_continuous_batching",
        "backend": jax.default_backend(),
        "segment_len": SEG,
        "model": {"n_layers": cfg.n_layers, "d_model": cfg.d_model,
                  "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
                  "num_mem_tokens": cfg.armt.num_mem_tokens},
        "baseline_one_by_one_tok_s": baseline_tok_s,
        "results": results,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    row("bench_serve_json", 0.0, out_path)
    return payload


def main(quick: bool = True):
    bench_serve(quick)


if __name__ == "__main__":
    main(quick=False)
