"""Paper Table 2: relative logits error (Frobenius) between the sequential
baseline and Diagonal Batching, vs number of segments, in fp32 and bf16.
The paper reports <= 2% for fp16 CUDA kernels; exact-reordering in JAX gives
orders of magnitude less."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.configs import get_smoke_config
from repro.models import forward_hidden, init_params
from repro.models.layers import norm
from repro.models.model import _head_matmul


def _rel_err(cfg, params, toks):
    hs, _ = forward_hidden(params, cfg, toks, schedule="sequential")
    hd, _ = forward_hidden(params, cfg, toks, schedule="diagonal")

    def logits(h):
        hn = norm(cfg.norm, h, params["final_norm"])
        return _head_matmul(params, cfg, hn).astype(jnp.float32)

    ls, ld = logits(hs), logits(hd)
    return float(jnp.linalg.norm(ls - ld) / jnp.linalg.norm(ls))


def _trained_params(cfg, steps: int):
    """The paper measures a *trained* ARMT (random-init recurrences are
    chaotic and exaggerate reordering drift) — train briefly first."""
    from repro.data import lm_stream
    from repro.optim import OptimConfig
    from repro.train.loop import train_loop
    ocfg = OptimConfig(lr=3e-3, total_steps=steps, warmup_steps=3)
    data = lm_stream(cfg.vocab, 4, 4 * cfg.armt.segment_len, seed=0)
    out = train_loop(cfg, ocfg, data, steps=steps, schedule="sequential")
    return out["state"]["params"]


def main(quick: bool = True):
    base = get_smoke_config("llama-1b-armt")
    seg = base.armt.segment_len
    cfg32 = dataclasses.replace(base, dtype="float32")
    params = _trained_params(cfg32, 100)   # undertrained recurrences are
    # chaotic and exaggerate reordering drift (see EXPERIMENTS.md §1.2)
    for dtype in ("float32", "bfloat16"):
        cfg = dataclasses.replace(base, dtype=dtype)
        p = (params if dtype == "float32" else
             jax.tree_util.tree_map(
                 lambda x: x.astype(jnp.bfloat16)
                 if x.dtype == jnp.float32 else x, params))
        for n_seg in (1, 2, 4, 8, 16, 32):
            if quick and n_seg > 16:
                continue
            toks = jax.random.randint(jax.random.PRNGKey(1),
                                      (1, n_seg * seg), 8, cfg.vocab)
            e = _rel_err(cfg, p, toks)
            row(f"error_accum_{dtype}_seg{n_seg}", 0.0,
                f"rel_logits_err_pct={e * 100:.5f};paper_bound_pct=2.0")


if __name__ == "__main__":
    main()
