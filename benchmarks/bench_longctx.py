"""Million-token bounded-memory prefill (DESIGN.md §15, ROADMAP
"Million-token workloads with bounded memory") -> ``BENCH_longctx.json``.

Three sections:

* ``memory_curve`` — compiled-program byte counts (AOT
  ``memory_analysis``; nothing runs) of the one-shot diagonal prefill at
  growing segment counts, streaming carry vs full-ys. The headline is
  ``temp_flat_ratio_stream``: the streaming executor's temp bytes — the
  activation memory the schedule actually holds live — must stay flat
  (<= 1.1x) from the smallest to the largest point (64k -> 1M tokens in
  the full run). Arguments (the embedded segments) and retained outputs
  (one row per segment) grow with S by construction — they are the data,
  not the working set — so the flatness claim is on temp bytes, with the
  full-ys mode's O(S·B·T·D) output recorded alongside for contrast.

* ``million_token_run`` — the long prefill actually runs on this backend
  under ``run_diagonal(stream_ys=True)`` (8192 segments x 128 tokens = 1M
  tokens in the full run; 32k in quick), wall clock and tok/s recorded.

* needle smoke — the run's tokens are a ``needle_qa`` instance; the
  retained last-segment row feeds ``last_logits`` and the argmax is
  recorded against the gold answer. The model is untrained (training to
  retrieval at 8k segments is far beyond smoke scale), so exact-match is
  chance, and — a *model* numerics property, not an executor one — the
  untrained ARMT normalizer ``z`` drifts until ``z^T phi(q)`` crosses
  zero somewhere beyond a few hundred segments, after which reads (and
  so logits) go non-finite identically under every schedule. The smoke
  therefore asserts *completion* (bounded-memory prefill over the full
  length) and records per-element finiteness, while a small-S bitwise
  check pins the streaming path to the full-width full-ys reference on
  the same needle data (EXPERIMENTS.md §Long-context).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import compiled_memory_stats, row
from repro.configs import ARMTConfig, get_smoke_config
from repro.core import diagonal as diag
from repro.core.schedule import StackLayout
from repro.data import needle_qa
from repro.models import init_params, last_logits
from repro.models.blocks import make_apply_block
from repro.models.grouped_blocks import resolve_grouped_apply
from repro.models.model import embed_segments, init_state

SEG = 128


def _config():
    # bench_diagonal's tiny 8-layer stack at the same segment length, so
    # the two artifacts' trajectories are comparable
    cfg = get_smoke_config("llama-1b-armt")
    return dataclasses.replace(
        cfg, n_layers=8, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, max_position=1 << 21,
        armt=ARMTConfig(segment_len=SEG, num_mem_tokens=8, d_mem=8))


def bench_longctx(quick: bool = True, out_path: str | None = None):
    cfg = _config()
    params = init_params(cfg, jax.random.PRNGKey(0))
    layout = StackLayout.from_config(cfg)
    exec_params = {"prelude": params.get("prelude", ()),
                   "pattern": params["pattern"]}
    apply = make_apply_block(cfg, mode="segmented", ssm_method="assoc")
    ga = resolve_grouped_apply(cfg, "fused", mode="segmented",
                               ssm_method="assoc")
    B, M = 1, cfg.armt.num_mem_tokens
    T = SEG + M
    dtype = params["embed"].dtype
    state0 = init_state(cfg, B, "segmented", dtype)

    def runner(stream, **kw):
        return jax.jit(lambda p, s0, x: diag.run_diagonal(
            layout, p, s0, x, apply, grouped_apply=ga, stream_ys=stream,
            retain_pos=SEG - 1, **kw))

    # ---- memory curve: AOT compile only, streaming vs full-ys ----------
    seg_counts = (64, 128, 256) if quick else (512, 2048, 8192)
    curve = []
    for S in seg_counts:
        x_abs = jax.ShapeDtypeStruct((S, B, T, cfg.d_model), dtype)
        rec = {"n_segments": S, "seq_len": S * SEG}
        for name, stream in (("full", False), ("stream", True)):
            mem = compiled_memory_stats(runner(stream), exec_params,
                                        state0, x_abs)
            rec[name] = mem
            row(f"longctx_mem_{name}_S{S}", 0.0,
                f"temp={mem['temp_bytes']} out={mem['output_bytes']} "
                f"arg={mem['argument_bytes']}")
        curve.append(rec)
    t0, t1 = curve[0]["stream"]["temp_bytes"], curve[-1]["stream"]["temp_bytes"]
    flat_ratio = (t1 / t0) if t0 else None
    row("longctx_temp_flat_ratio", 0.0,
        f"stream temp {seg_counts[0]}->{seg_counts[-1]} segs: "
        f"{flat_ratio:.3f}x (acceptance <= 1.1x)")

    # ---- small-S bitwise pin: stream vs full-width full-ys -------------
    S0 = 16
    test0 = next(needle_qa(cfg.vocab, B, S0 * SEG, seed=11, n_keys=4))
    segs0 = embed_segments(params, cfg, jnp.asarray(test0["tokens"]), SEG,
                           True)
    ys, st_f = diag.run_diagonal(layout, exec_params, state0, segs0, apply,
                                 grouped_apply=ga, band_skip=False)
    sd, st_s = diag.run_diagonal(layout, exec_params, state0, segs0, apply,
                                 grouped_apply=ga, stream_ys=True,
                                 retain_pos=SEG - 1)
    assert (sd["brow"] == ys[:, :, SEG - 1]).all(), \
        "stream retained rows diverged from full-ys reference"
    assert all((a == b).all() for a, b in
               zip(jax.tree_util.tree_leaves(st_s),
                   jax.tree_util.tree_leaves(st_f)))
    row("longctx_bitwise_pin", 0.0, f"S={S0} stream==full-ys OK")

    # ---- the long run: streaming prefill + needle smoke ----------------
    S_run = seg_counts[-1]
    L_run = S_run * SEG
    test = next(needle_qa(cfg.vocab, B, L_run, seed=7, n_keys=4,
                          needle_region=(0.55, 0.95)))
    toks = jnp.asarray(test["tokens"])
    segs = embed_segments(params, cfg, toks, SEG, True)
    run = runner(True)
    sd, _st = jax.block_until_ready(run(exec_params, state0, segs))  # compile
    t0 = time.perf_counter()
    sd, _st = jax.block_until_ready(run(exec_params, state0, segs))
    wall = time.perf_counter() - t0
    logits = last_logits(params, cfg, sd["brow"][:, :, None, :])
    pred = int(jnp.argmax(logits[0]))
    gold = int(np.asarray(test["answer"])[0])
    finite = bool(jnp.isfinite(logits).all())
    finite_frac = float(jnp.isfinite(sd["brow"]).mean())
    carry_bytes = int(sd["win"].nbytes + sd["brow"].nbytes)
    million = {
        "n_segments": S_run, "seq_len": L_run, "wall_s": wall,
        "tok_s": L_run / wall, "retained_bytes": carry_bytes,
        "needle": {"pred": pred, "gold": gold,
                   "exact_match": pred == gold, "logits_finite": finite,
                   "retained_finite_frac": finite_frac,
                   "note": "untrained model: accuracy is chance and the "
                           "ARMT z-normalizer drifts non-finite beyond a "
                           "few hundred segments under every schedule; "
                           "the smoke asserts completion, the small-S "
                           "bitwise pin asserts exactness"},
    }
    row("longctx_prefill", wall,
        f"{L_run} tokens ({S_run} segs) {L_run / wall:.0f} tok/s "
        f"retained={carry_bytes} B")

    out_path = out_path or os.environ.get("BENCH_LONGCTX_OUT",
                                          "BENCH_longctx.json")
    payload = {
        "bench": "longctx_stream_prefill",
        "backend": jax.default_backend(),
        "segment_len": SEG,
        "model": {"n_layers": cfg.n_layers, "d_model": cfg.d_model,
                  "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
                  "num_mem_tokens": M},
        "memory_curve": curve,
        "temp_flat_ratio_stream": flat_ratio,
        "million_token_run": million,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    row("bench_longctx_json", 0.0, out_path)
    return payload


def main(quick: bool = True):
    bench_longctx(quick)


if __name__ == "__main__":
    main(quick=False)
