"""Schedule comparison on any assigned architecture (reduced config):
equivalence (paper §4.5) + wall-time scaling (paper §4.3), and the HLO-level
serialization argument — sequential lowers to S*L serialized layer bodies,
diagonal to S+L-1 grouped bodies.

    PYTHONPATH=src python examples/compare_schedules.py --arch jamba-1.5-large-398b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.models import forward_hidden, init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b",
                    choices=ASSIGNED_ARCHS)
    ap.add_argument("--n-seg", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    seg = cfg.armt.segment_len if cfg.armt else 16
    L_tokens = args.n_seg * seg
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, L_tokens),
                              8, cfg.vocab)
    kw = {}
    if cfg.encoder is not None:
        kw["enc_frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (2, cfg.encoder.n_frames, cfg.d_model))

    outs = {}
    for sched in ("sequential", "diagonal"):
        fwd = jax.jit(lambda p, t, s=sched: forward_hidden(
            p, cfg, t, schedule=s, **kw)[0])
        h = jax.block_until_ready(fwd(params, toks))
        t0 = time.perf_counter()
        jax.block_until_ready(fwd(params, toks))
        dt = time.perf_counter() - t0
        outs[sched] = (h, dt)
        # count scan trip counts in the lowered HLO (the serialization metric)
        hlo = fwd.lower(params, toks).compile().as_text()
        n_while = hlo.count(" while(")
        print(f"{args.arch} [{sched:10s}]  {dt:6.3f}s   "
              f"while-loops in HLO: {n_while}")

    d = float(jnp.abs(outs['sequential'][0] - outs['diagonal'][0]).max())
    print(f"max |sequential - diagonal| = {d:.3e} "
          f"(exact recurrence preserved)")
    print(f"speedup diagonal vs sequential: "
          f"{outs['sequential'][1] / outs['diagonal'][1]:.2f}x "
          f"({args.n_seg} segments x {cfg.n_layers} layers)")


if __name__ == "__main__":
    main()
