"""End-to-end training driver: train an ARMT on needle-QA so that retrieval
crosses a segment boundary (only solvable through the associative memory),
with checkpointing + resume, then evaluate exact-match accuracy under both
schedules.

    PYTHONPATH=src python examples/train_needle.py [--steps 600]
At --full-scale the config is a ~100M-parameter Llama-ARMT (for real
accelerators; the default runs on CPU in minutes).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARMTConfig, get_config, get_smoke_config
from repro.data import needle_qa
from repro.models import forward_hidden, last_logits
from repro.optim import OptimConfig
from repro.train.loop import train_loop

SEG = 32


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_needle_ckpt")
    ap.add_argument("--full-scale", action="store_true",
                    help="~100M-param config (accelerator recommended)")
    args = ap.parse_args()

    if args.full_scale:
        cfg = get_config("llama-160m-armt")     # ~160M, the paper's smallest
        seg = cfg.armt.segment_len
    else:
        cfg = dataclasses.replace(
            get_smoke_config("llama-1b-armt"),
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
            d_ff=128, armt=ARMTConfig(segment_len=SEG, num_mem_tokens=8,
                                      d_mem=8))
        seg = SEG

    ocfg = OptimConfig(lr=3e-3, total_steps=args.steps, warmup_steps=10,
                       weight_decay=0.0)
    data = needle_qa(cfg.vocab, 32, 4 * seg, seed=0, n_keys=4,
                     needle_region=(0.55, 0.95))

    def log(m):
        print(f"step {m['step']:4d} loss {m['loss']:.4f}", flush=True)

    out = train_loop(cfg, ocfg, data, steps=args.steps,
                     ckpt_dir=args.ckpt_dir, ckpt_every=100,
                     schedule="sequential", log_fn=log, log_every=50)
    params = out["state"]["params"]

    print("\nexact-match accuracy (chance = 0.25):")
    for region, name in [((0.80, 0.92), "needle in query segment"),
                         ((0.55, 0.72), "needle in PREVIOUS segment")]:
        test = next(needle_qa(cfg.vocab, 64, 4 * seg, seed=999, n_keys=4,
                              needle_region=region))
        toks = jnp.asarray(test["tokens"])
        for sched in ("sequential", "diagonal"):
            logits = last_logits(params, cfg, forward_hidden(
                params, cfg, toks, schedule=sched)[0])
            acc = float((np.asarray(jnp.argmax(logits, -1))
                         == test["answer"]).mean())
            print(f"  {name:30s} {sched:10s}: {acc:.3f}")


if __name__ == "__main__":
    main()
