"""End-to-end serving driver (the paper's deployment scenario): batched
long-context requests served with diagonal-batching prefill and
constant-memory ARMT decode.

Compares, on the same model:
  * sequential vs diagonal prefill wall time (paper Tables 1/9)
  * ARMT decode state size vs an equivalent full-attention KV cache
    (paper Fig. 1: 167x memory saving at 128k)

    PYTHONPATH=src python examples/long_context_inference.py [--long]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import ARMTConfig, get_smoke_config
from repro.models import decode_state_init, init_params
from repro.serve import ServeEngine
from repro.utils import fmt_bytes, tree_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--long", action="store_true",
                    help="16k-token prompts (slower)")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    seg = 128
    cfg = dataclasses.replace(
        get_smoke_config("llama-1b-armt"),
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
        max_position=1 << 17,
        armt=ARMTConfig(segment_len=seg, num_mem_tokens=8, d_mem=8))
    params = init_params(cfg, jax.random.PRNGKey(0))
    P = (16384 if args.long else 4096)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (args.batch, P),
                                 8, cfg.vocab)
    print(f"model: {cfg.n_layers}L d={cfg.d_model}; prompt {P} tokens "
          f"({P // seg} segments of {seg}); batch {args.batch}")

    for sched in ("sequential", "diagonal"):
        eng = ServeEngine(params, cfg, serve_mode="armt", schedule=sched,
                          max_len=P + args.max_new)
        t0 = time.perf_counter()
        res = eng.generate(prompts, args.max_new)
        dt = time.perf_counter() - t0
        print(f"  {sched:10s} prefill+decode: {dt:7.2f}s "
              f"tokens={res.tokens.shape}")

    # memory: ARMT state vs full-attention KV cache at this context length
    armt_state = jax.eval_shape(lambda: decode_state_init(
        cfg, args.batch, serve_mode="armt", max_len=P, dtype=jnp.float32))
    kv_state = jax.eval_shape(lambda: decode_state_init(
        cfg, args.batch, serve_mode="cache", max_len=P, dtype=jnp.float32))
    a, k = tree_bytes(armt_state), tree_bytes(kv_state)
    print(f"decode state: ARMT {fmt_bytes(a)} vs full KV {fmt_bytes(k)} "
          f"({k / a:.1f}x saving; grows with context for KV, constant for ARMT)")


if __name__ == "__main__":
    main()
