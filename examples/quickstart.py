"""Quickstart: build a small ARMT, run both schedules, verify they agree,
train a few steps, generate.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ARMTConfig, get_smoke_config
from repro.data import lm_stream
from repro.models import forward_hidden, init_params
from repro.optim import OptimConfig
from repro.serve import ServeEngine
from repro.train.loop import train_loop


def main():
    # 1. a small ARMT (same family as the paper's Llama-ARMT)
    cfg = dataclasses.replace(
        get_smoke_config("llama-1b-armt"),
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
        armt=ARMTConfig(segment_len=32, num_mem_tokens=8, d_mem=8))
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 8, cfg.vocab)

    # 2. the paper's claim: diagonal batching is a pure reordering
    h_seq, _ = forward_hidden(params, cfg, toks, schedule="sequential")
    h_diag, _ = forward_hidden(params, cfg, toks, schedule="diagonal")
    print(f"schedules agree: max|Δ| = {float(jnp.abs(h_seq - h_diag).max()):.2e}")

    # 3. train a few steps (fault-tolerant loop, NaN-skip, AdamW)
    ocfg = OptimConfig(lr=3e-3, total_steps=20, warmup_steps=2)
    out = train_loop(cfg, ocfg, lm_stream(cfg.vocab, 4, 128), steps=20,
                     schedule="auto")
    print(f"loss: {out['history'][0]['loss']:.3f} -> "
          f"{out['history'][-1]['loss']:.3f}")

    # 4. serve: diagonal prefill + constant-memory ARMT decode
    eng = ServeEngine(out["state"]["params"], cfg, serve_mode="armt",
                      schedule="diagonal", max_len=256)
    res = eng.generate(toks, max_new=8)
    print(f"generated {res.tokens.shape} tokens "
          f"(prefill segments: {res.prefill_segments})")
    print("ok")


if __name__ == "__main__":
    main()
