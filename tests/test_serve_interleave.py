"""Interleaved chunked prefill (DESIGN.md §11): the resumable diagonal
pipeline is bit-exact vs the one-shot executor, and interleaved/fused
admission is token-identical (greedy) to the blocking path across admission
timings, segment phases, prefix-cache hits, and session resume; the
suspended carry never aliases store entries or the decode pool (the
donation-safety regression); requests are pulled lazily from a live source.
An 8-fake-device mesh variant runs in a slow-marked subprocess (the
test_serve_sharded.py pattern)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import diagonal as D
from repro.core.schedule import (StackLayout, n_diagonal_groups,
                                 segments_completed, segments_entered)
from repro.models import init_params, init_state
from repro.models.blocks import make_apply_block
from repro.serve import (ContinuousScheduler, PrefixCache, Request,
                         ServeEngine, SessionStore, StreamEvent)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("h2o-danube-1.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _toks(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(8, cfg.vocab, (n,)).astype(np.int32)


def _requests(cfg, lens, max_new, seed=0):
    return [Request(req_id=f"r{i}", prompt=_toks(cfg, L, seed=seed + i),
                    max_new=max_new)
            for i, L in enumerate(lens)]


def _collect(events):
    outs = {}
    for ev in events:
        assert isinstance(ev, StreamEvent), ev
        outs.setdefault(ev.req_id, []).append(ev.token)
    return outs


# ---------------------------------------------------------------------------
# Core stepper: suspend/resume is exact
# ---------------------------------------------------------------------------

def test_pipeline_stepper_bitexact_vs_run_diagonal(setup):
    """pipeline_init/step/finalize reproduce run_diagonal bit-for-bit for
    every group budget — including budgets that overshoot the final group
    (masked no-op steps) — with and without capture."""
    cfg, params = setup
    layout = StackLayout.from_config(cfg)
    apply = make_apply_block(cfg, mode="segmented", ssm_method="assoc")
    ep = {"prelude": params["prelude"], "pattern": params["pattern"]}
    S, B = 5, 1
    T = cfg.armt.segment_len + cfg.armt.num_mem_tokens
    segs = jax.random.normal(jax.random.PRNGKey(1), (S, B, T, cfg.d_model))
    st0 = init_state(cfg, B, "segmented", jnp.float32)
    n_steps = n_diagonal_groups(S, layout.n_layers)

    ys_ref, fin_ref, cap_ref = D.run_diagonal(layout, ep, st0, segs, apply,
                                              capture_states=True)
    bs_ref = D.boundary_states_from_capture(layout, cap_ref, S)

    for k in (1, 3, n_steps, n_steps + 5):
        xs, carry = D.pipeline_init(layout, st0, segs, capture_states=True)
        step = jax.jit(lambda p, x, c, _k=k: D.pipeline_step(
            layout, p, x, c, apply, n_groups=_k))
        done = 0
        while done < n_steps:
            carry = step(ep, xs, carry)
            done += k
        ys, fin, cap = D.pipeline_finalize(layout, carry)
        np.testing.assert_array_equal(np.asarray(ys), np.asarray(ys_ref))
        for a, b in zip(jax.tree_util.tree_leaves(fin),
                        jax.tree_util.tree_leaves(fin_ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(cap),
                        jax.tree_util.tree_leaves(bs_ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pipeline_cursors():
    """Fill/drain cursor bookkeeping of a suspended pipeline (schedule.py):
    segment s enters at group s and finishes at group s + L - 1; both clip
    at the grid edges (the stepper's overshoot steps)."""
    S, L = 5, 3
    n = n_diagonal_groups(S, L)
    assert n == 7
    assert [segments_entered(i, S, L) for i in range(n + 2)] == \
        [0, 1, 2, 3, 4, 5, 5, 5, 5]
    assert [segments_completed(i, S, L) for i in range(n + 2)] == \
        [0, 0, 0, 1, 2, 3, 4, 5, 5]


# ---------------------------------------------------------------------------
# Token identity: interleaved / fused admission vs blocking
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(prefill_groups_per_chunk=1),
    dict(prefill_groups_per_chunk=3),
    dict(prefill_groups_per_chunk=64),     # whole prefill in one advance
    dict(prefill_groups_per_chunk=2, fused_admission=True),
])
def test_interleaved_token_identity(setup, kw):
    """Acceptance: interleaved (and fused) admission == blocking admission
    == single-request generate, token for token, across admission timings
    (more requests than slots), segment phases (mid-segment / at-boundary
    prompts), and group budgets from 1 to whole-prefill-per-call."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256)
    lens = [2 * seg, 2 * seg + 1, seg - 1, 13, 3 * seg + seg // 2]
    max_new = 6
    reqs = _requests(cfg, lens, max_new)
    blocking = _collect(eng.serve(list(reqs), n_slots=3, chunk=4,
                                  prefill_groups_per_chunk=0))
    got = _collect(eng.serve(list(reqs), n_slots=3, chunk=4, **kw))
    assert got == blocking
    for r in reqs:
        ref = eng.generate(jnp.asarray(r.prompt)[None], max_new).tokens[0]
        assert got[r.req_id] == ref.tolist(), r.req_id


def test_interleaved_prefix_cache_hits(setup):
    """Interleaved admission through a prefix-cached engine: identical
    tokens AND identical cache behavior (hits, insertions) to blocking —
    the pipeline's capture path feeds the cache like the one-shot drain.
    Pinned to max_concurrent_admissions=1 (the single-carry pipeline this
    test targets): the pooled default admits followers concurrently, and
    a follower racing the first member's insert legitimately misses
    (DESIGN.md §12; covered by test_serve_concurrent.py's
    test_concurrent_prefix_cache_identity)."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    sys_p = _toks(cfg, 3 * seg, seed=20)
    prompts = [np.concatenate([sys_p, _toks(cfg, 5, seed=21 + i)])
               for i in range(3)]
    stats = {}
    outs = {}
    for mode, k in (("blocking", 0), ("interleaved", 2)):
        cache = PrefixCache(seg, max_bytes=64 << 20)
        eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                          prefix_cache=cache)
        reqs = [Request(f"p{i}", p, 6) for i, p in enumerate(prompts)]
        outs[mode] = _collect(eng.serve(reqs, n_slots=2, chunk=3,
                                        prefill_groups_per_chunk=k,
                                        max_concurrent_admissions=1))
        st = cache.stats.as_dict()
        stats[mode] = (st["hits"], st["insertions"], st["collisions"])
    assert outs["interleaved"] == outs["blocking"]
    assert stats["interleaved"] == stats["blocking"]
    assert stats["interleaved"][0] >= 1        # the shared prefix did hit


def test_interleaved_session_resume(setup):
    """Sessions across serve() calls under interleaved admission: turn 2
    resumes the stored state token-identically to the blocking scheduler
    (and the resume admission itself is interleave-driven tail pieces)."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    t1, t2 = _toks(cfg, 2 * seg + 3, seed=30), _toks(cfg, 9, seed=31)
    got = {}
    for mode, k in (("blocking", 0), ("interleaved", 2)):
        store = SessionStore(max_bytes=64 << 20)
        eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                          session_store=store)
        o1 = _collect(eng.serve(
            [Request("a", t1, 6, session_id="c"),
             Request("x", _toks(cfg, 5, seed=32), 4)],
            n_slots=2, chunk=3, prefill_groups_per_chunk=k))
        o2 = _collect(eng.serve([Request("b", t2, 6, session_id="c")],
                                n_slots=2, chunk=3,
                                prefill_groups_per_chunk=k))
        got[mode] = (o1["a"], o1["x"], o2["b"])
    assert got["interleaved"] == got["blocking"]


def test_admission_mid_segment_and_at_boundary(setup):
    """Admissions that land while decoding slots sit mid-segment and
    exactly at a segment boundary: run enough steady tokens that the
    admission's interleaved chunks bracket a flush."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256)
    # steady request crosses a boundary mid-decode while the long prompt
    # is being admitted a group at a time
    reqs = [Request("steady", _toks(cfg, seg - 2, seed=40), 2 * seg),
            Request("long", _toks(cfg, 4 * seg, seed=41), 5)]
    blocking = _collect(eng.serve(list(reqs), n_slots=2, chunk=2,
                                  prefill_groups_per_chunk=0))
    for k in (1, 2):
        got = _collect(eng.serve(list(reqs), n_slots=2, chunk=2,
                                 prefill_groups_per_chunk=k))
        assert got == blocking, k


# ---------------------------------------------------------------------------
# Donation safety: the suspended carry aliases nothing it doesn't own
# ---------------------------------------------------------------------------

def _leaf_ptrs(tree):
    return {l.unsafe_buffer_pointer()
            for l in jax.tree_util.tree_leaves(tree)
            if isinstance(l, jax.Array)}


def test_suspended_carry_never_aliases_stores_or_pool(setup):
    """Regression (PR 4's fresh-buffer guarantee, extended to the
    pipeline): the jitted stepper donates its carry, so a carry leaf that
    aliased a prefix-cache snapshot would delete the store's arrays on the
    first advance; and a decode chunk that donates the pool between
    advances must not invalidate a suspended carry. Donation is a no-op on
    CPU, so this asserts the invariant directly (buffer-pointer
    disjointness) and then simulates donation by deleting the pool arrays
    a donating chunk would have consumed."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    cache = PrefixCache(seg, max_bytes=64 << 20)
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      prefix_cache=cache)
    warm = np.concatenate([_toks(cfg, 3 * seg, seed=50), _toks(cfg, 4, seed=51)])
    eng.generate(warm[None], 3)                       # fills the cache
    snap_ptrs = set()
    for slot in cache._lru.entries.values():
        snap_ptrs |= _leaf_ptrs(slot.payload)

    prompt = np.concatenate([warm[:3 * seg], _toks(cfg, 4, seed=52)])
    pipe = eng.start_prefill(prompt[None], groups_per_call=1)
    assert pipe.cached == 3
    pipe.advance()
    carry_ptrs = _leaf_ptrs(pipe._carry) if pipe._carry is not None else set()

    # a decode chunk that donates its pool between advances
    from repro.serve.scheduler import scheduler_fns
    from repro.models import decode_state_init
    chunk_fn, _, _ = scheduler_fns(eng, 2)
    pool = decode_state_init(cfg, 2, serve_mode="armt", max_len=256,
                             dtype=jnp.float32, per_slot_pos=True)
    pool_ptrs = _leaf_ptrs(pool)
    tok = jnp.zeros((2,), jnp.int32)
    active = jnp.ones((2,), bool)
    remaining = jnp.full((2,), 4, jnp.int32)
    out = chunk_fn(eng.params, pool, tok, active, remaining)

    assert not (carry_ptrs & snap_ptrs), "carry aliases the prefix cache"
    assert not (carry_ptrs & pool_ptrs), "carry aliases the decode pool"
    # simulate the donation the jitted chunk would perform on GPU/TPU:
    # delete the pre-chunk pool buffers, then resume the suspended prefill
    jax.block_until_ready(out)
    for leaf in jax.tree_util.tree_leaves(pool):
        leaf.delete()
    while not pipe.advance():
        pass
    logits, dstate, pos, cached = pipe.result()
    ref = eng._prefill(jnp.asarray(prompt)[None])
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref[0]))
    assert pos == ref[2] and cached == ref[3]
    # and the cache survived the donated carry: a fresh admission still hits
    pipe2 = eng.start_prefill(prompt[None], groups_per_call=4)
    assert pipe2.cached == 3
    while not pipe2.advance():
        pass
    np.testing.assert_array_equal(np.asarray(pipe2.result()[0]),
                                  np.asarray(ref[0]))


# ---------------------------------------------------------------------------
# Lazy request pull (live sources)
# ---------------------------------------------------------------------------

def test_lazy_pull_serves_live_source(setup):
    """The scheduler pulls requests between chunks instead of draining the
    iterable up front: a source that requires request 1's tokens to have
    streamed before yielding request 2 completes (it would assert under
    the old drain-everything-first loop), and t_submit is per-request pull
    time, so the later request's TTFT excludes the earlier one's decode."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256)
    events = []

    def source():
        yield Request("a", _toks(cfg, seg, seed=60), 4)
        assert any(isinstance(e, StreamEvent) and e.req_id == "a"
                   for e in events), "source was drained eagerly"
        yield Request("b", _toks(cfg, seg, seed=61), 4)

    sched = ContinuousScheduler(eng, n_slots=1, chunk=2)
    for ev in sched.run(source()):
        events.append(ev)
    done = [e for e in events if e.done]
    assert {e.req_id for e in done} == {"a", "b"}
    assert len(sched.admission_windows) == 2
    a_done = next(e for e in done if e.req_id == "a")
    b_done = next(e for e in done if e.req_id == "b")
    # b was pulled after a finished: its submission-relative TTFT must not
    # include a's entire service time (it would under the shared-t0 clock)
    assert b_done.ttft_s < a_done.ttft_s + a_done.t_emit - events[0].t_emit
    assert all(e.t_emit is not None for e in events)


def test_live_source_defers_with_none(setup):
    """A live source yields None for 'no request ready yet': the scheduler
    keeps decoding (instead of blocking inside next() while active streams
    starve) and picks the next request up at a later chunk boundary."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256)
    events = []
    polls = {"n": 0}

    def source():
        yield Request("a", _toks(cfg, seg, seed=65), 6)
        # "nothing ready" until a finishes decoding (3 chunks) — the
        # scheduler must keep chunking instead of blocking in next()
        while not any(isinstance(e, StreamEvent) and e.req_id == "a"
                      and e.done for e in events):
            polls["n"] += 1
            yield None
        yield Request("b", _toks(cfg, seg, seed=66), 4)

    sched = ContinuousScheduler(eng, n_slots=2, chunk=2)
    for ev in sched.run(source()):
        events.append(ev)
    done = {e.req_id for e in events if isinstance(e, StreamEvent) and e.done}
    assert done == {"a", "b"}
    assert polls["n"] >= 1       # the deferral path actually exercised


def test_push_model_free_slots_count_as_capacity(setup):
    """With an interleaved admission in flight, a free slot is spoken-for
    capacity, not dead: queued requests may exceed max_queue by the free
    slot count, and queue_full fires only when slots AND backlog are
    exhausted (regression: the first interleaved implementation rejected
    while slots sat idle)."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256)
    reqs = [Request("a", _toks(cfg, 4 * seg, seed=70), 3),   # long admission
            Request("b", _toks(cfg, 5, seed=71), 3),
            Request("c", _toks(cfg, 5, seed=72), 3),          # fits: free slot
            Request("d", _toks(cfg, 5, seed=73), 3)]          # true overflow
    evs = list(eng.serve(reqs, n_slots=2, chunk=2, max_queue=1,
                         prefill_groups_per_chunk=1))
    errs = {e.req_id: e.code for e in evs
            if not isinstance(e, StreamEvent)}
    assert errs == {"d": "queue_full"}, errs
    done = {e.req_id for e in evs if isinstance(e, StreamEvent) and e.done}
    assert done == {"a", "b", "c"}


# ---------------------------------------------------------------------------
# 8-fake-device mesh variant (subprocess, slow-marked)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import dataclasses
import numpy as np
import jax
jax.config.update("jax_default_matmul_precision", "highest")
from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve import Request, ServeEngine
from repro.launch.mesh import parse_mesh

cfg = dataclasses.replace(get_smoke_config("h2o-danube-1.8b"), n_kv_heads=4)
params = init_params(cfg, jax.random.PRNGKey(0))
seg = cfg.armt.segment_len
rng = np.random.default_rng(7)

ref_eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256)
reqs = [Request(req_id=f"r{i}",
                prompt=rng.integers(8, cfg.vocab, (L,)).astype(np.int32),
                max_new=5)
        for i, L in enumerate([2 * seg, seg + 3, 7, seg - 1])]
refs = {r.req_id: ref_eng.generate(np.asarray(r.prompt)[None], 5).tokens[0]
        for r in reqs}

for spec in ("data=2,model=4", "stage=2,model=4"):
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      mesh=parse_mesh(spec))
    for kw in (dict(prefill_groups_per_chunk=2),
               dict(prefill_groups_per_chunk=2, fused_admission=True)):
        outs = {}
        for ev in eng.serve(list(reqs), n_slots=2, chunk=3, **kw):
            outs.setdefault(ev.req_id, []).append(ev.token)
        for r in reqs:
            assert outs[r.req_id] == refs[r.req_id].tolist(), (spec, kw, r.req_id)
    print(f"OK interleave_{spec.split(',')[0].split('=')[0]}")
"""


@pytest.mark.slow
def test_interleaved_admission_sharded_token_identical():
    """Interleaved + fused admission on an 8-fake-device mesh (TP and
    stage-pipeline meshes) is token-identical to the single-device blocking
    reference — the suspended carry crosses GSPMD programs via
    pipeline_carry_specs. Subprocess because XLA_FLAGS must be set before
    jax imports (test_serve_sharded.py pattern); timeout skips."""
    try:
        r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                           capture_output=True, text=True, timeout=600,
                           env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                "HOME": "/root"})
    except subprocess.TimeoutExpired:
        pytest.skip("interleaved-mesh subprocess exceeded 600s: environment "
                    "too constrained to compile the 8-fake-device GSPMD "
                    "programs — exactness is asserted whenever the compile "
                    "finishes (CI runs this in the sharded-serving step)")
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-2000:])
    for m in ("interleave_data", "interleave_stage"):
        assert f"OK {m}" in r.stdout, (m, r.stdout[-1000:])
