"""Per-architecture smoke tests: reduced same-family config, one forward and
one train step on CPU, asserting output shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, get_smoke_config
from repro.models import forward_hidden, init_params
from repro.optim import OptimConfig
from repro.train import init_train_state, make_train_step


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + ["llama-1b-armt"])
def test_full_config_is_exact_assignment(arch):
    cfg = get_config(arch)
    cfg.validate()
    # spot checks against the assignment table
    table = {
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
    }
    if arch in table:
        L, d, H, kv, dff, V = table[arch]
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, H, kv, dff, V)


@pytest.mark.parametrize("arch", [
    # jamba's 8-type pattern makes its forward+train compile dominate the
    # tier-1 wall-clock — CI still runs it via -m "slow or not slow"
    pytest.param(a, marks=pytest.mark.slow)
    if a == "jamba-1.5-large-398b" else a
    for a in ASSIGNED_ARCHS])
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 8, cfg.vocab)
    kw = {}
    if cfg.encoder is not None:
        kw["enc_frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder.n_frames, cfg.d_model))
    h, fin = forward_hidden(params, cfg, toks, schedule="diagonal", **kw)
    seg = cfg.armt.segment_len if cfg.armt else 1024
    n_seg = S // min(seg, S)
    assert h.shape[0] == n_seg and h.shape[1] == B and h.shape[-1] == cfg.d_model
    assert np.isfinite(np.asarray(h, np.float32)).all(), f"{arch} NaN hidden"

    ocfg = OptimConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    step = make_train_step(cfg, ocfg, schedule="sequential")
    state = init_train_state(cfg, ocfg, jax.random.PRNGKey(3))
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1), **kw}
    state2, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch} loss NaN"
    assert float(metrics["loss"]) > 0
    # params actually changed
    d0 = jax.tree_util.tree_leaves(state["params"])[3]
    d1 = jax.tree_util.tree_leaves(state2["params"])[3]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))
