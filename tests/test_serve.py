"""Continuous-batching serving: the multi-request scheduler is
token-identical (greedy) to running each request alone through the
single-request engine; per-slot positions and jnp.where-masked flushes; the
on-device decode loop matches a host-stepped reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import (decode_state_init, decode_step, flush_segment,
                          init_params, mask_decode_state)
from repro.serve import (ContinuousScheduler, Request, RequestError,
                         ServeEngine, StreamEvent)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("h2o-danube-1.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _requests(cfg, lens, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(req_id=f"r{i}",
                    prompt=rng.integers(8, cfg.vocab, (L,)).astype(np.int32),
                    max_new=max_new)
            for i, L in enumerate(lens)]


def _collect(events):
    outs = {}
    done = {}
    for ev in events:
        outs.setdefault(ev.req_id, []).append(ev.token)
        assert ev.index == len(outs[ev.req_id]) - 1
        if ev.done:
            done[ev.req_id] = True
    return outs, done


def test_scheduler_token_identical_to_single_request(setup):
    """Acceptance: mixed prompt lengths and segment-boundary phases through
    the pooled scheduler == each request alone, greedy, token for token.
    More requests than slots exercises freeing + re-admission; chunk not
    dividing max_new exercises mid-chunk completion."""
    cfg, params = setup
    seg = cfg.armt.segment_len                     # 16 in the smoke config
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256)
    # phases: tail-free (2*seg), one off either side of a boundary, odd tails
    lens = [2 * seg, 2 * seg + 1, seg - 1, 13, 3 * seg + seg // 2]
    max_new = 7
    reqs = _requests(cfg, lens, max_new)
    outs, done = _collect(eng.serve(reqs, n_slots=3, chunk=4))
    assert set(done) == {r.req_id for r in reqs}
    for r in reqs:
        ref = eng.generate(jnp.asarray(r.prompt)[None], max_new).tokens[0]
        assert outs[r.req_id] == ref.tolist(), r.req_id


def test_scheduler_cache_mode(setup):
    cfg, params = setup
    cfg = dataclasses.replace(cfg, armt=None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, serve_mode="cache", max_len=64)
    # KV-cache overflow is refused, not silently clamped: generate raises,
    # the scheduler streams a structured RequestError (never raises
    # mid-serve — see test_state_store.py for the full error-event matrix)
    with pytest.raises(ValueError, match="max_len"):
        eng.generate(jnp.zeros((1, 60), jnp.int32), 5)
    evs = list(eng.serve(_requests(cfg, [60], 5), n_slots=1))
    assert [type(e) for e in evs] == [RequestError]
    assert evs[0].code == "invalid_request" and "max_len" in evs[0].message
    reqs = _requests(cfg, [9, 21, 14], 5)
    outs, done = _collect(eng.serve(reqs, n_slots=2, chunk=3))
    assert len(done) == 3
    for r in reqs:
        ref = eng.generate(jnp.asarray(r.prompt)[None], 5).tokens[0]
        assert outs[r.req_id] == ref.tolist(), r.req_id


def test_generate_matches_host_stepped_reference(setup):
    """The on-device lax.scan decode loop (flush via lax.cond, sampling on
    device) reproduces a token-by-token host loop exactly."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=128)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, seg + 5), 8,
                                 cfg.vocab)
    max_new = 2 * seg    # crosses at least one segment boundary mid-decode
    got = eng.generate(prompts, max_new).tokens

    logits, st, pos, _cached = eng._prefill(prompts)
    step = jax.jit(lambda s, t: decode_step(params, cfg, s, t,
                                            serve_mode="armt"))
    flush = jax.jit(lambda s: flush_segment(params, cfg, s))
    want = np.zeros((2, max_new), np.int32)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(max_new):
        want[:, i] = np.asarray(tok)
        if i == max_new - 1:
            break
        logits, st = step(st, tok)
        pos += 1
        if pos >= seg:
            st = flush(st)
            pos = 0
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    np.testing.assert_array_equal(got, want)


def test_generate_sampling_determinism_and_validity(setup):
    cfg, params = setup
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=64)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 20), 8, cfg.vocab)
    r1 = eng.generate(prompts, 6, temperature=0.7, top_k=4, seed=11)
    r2 = eng.generate(prompts, 6, temperature=0.7, top_k=4, seed=11)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)   # same seed, same out
    assert r1.tokens.min() >= 0 and r1.tokens.max() < cfg.vocab
    r3 = eng.generate(prompts, 6, temperature=5.0, top_k=0, seed=12)
    assert r3.tokens.shape == (2, 6)


def test_per_slot_pos_matches_scalar_pos(setup):
    """decode_step with a per-slot pos vector (all rows at the same phase)
    == the scalar-pos path, logits and cache contents."""
    cfg, params = setup
    B = 2
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, 6), 8, cfg.vocab)
    st_s = decode_state_init(cfg, B, serve_mode="armt", max_len=64,
                             dtype=jnp.float32)
    st_v = decode_state_init(cfg, B, serve_mode="armt", max_len=64,
                             dtype=jnp.float32, per_slot_pos=True)
    assert st_s["pos"].shape == () and st_v["pos"].shape == (B,)
    step = jax.jit(lambda s, t: decode_step(params, cfg, s, t,
                                            serve_mode="armt"))
    for t in range(toks.shape[1]):
        la, st_s = step(st_s, toks[:, t])
        lb, st_v = step(st_v, toks[:, t])
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(st_v["pos"]),
                                  np.full((B,), toks.shape[1]))
    for a, b in zip(jax.tree_util.tree_leaves(
            {"prelude": st_s["prelude"], "pattern": st_s["pattern"]}),
            jax.tree_util.tree_leaves(
            {"prelude": st_v["prelude"], "pattern": st_v["pattern"]})):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


def test_masked_flush_touches_only_masked_rows(setup):
    """flush_segment(slot_mask): flushed rows get the memory update + cache
    and pos reset; unmasked rows are bit-identical untouched."""
    cfg, params = setup
    B = 3
    st = decode_state_init(cfg, B, serve_mode="armt", max_len=64,
                           dtype=jnp.float32, per_slot_pos=True)
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, cfg.armt.segment_len),
                              8, cfg.vocab)
    step = jax.jit(lambda s, t: decode_step(params, cfg, s, t,
                                            serve_mode="armt"))
    for t in range(toks.shape[1]):
        _, st = step(st, toks[:, t])
    mask = jnp.array([True, False, True])
    out = flush_segment(params, cfg, st, slot_mask=mask)
    full = flush_segment(params, cfg, st)            # all-rows reference

    np.testing.assert_array_equal(np.asarray(out["pos"]),
                                  [0, cfg.armt.segment_len, 0])
    for name in ("prelude", "pattern"):
        ax = 0 if name == "prelude" else 1
        for o, s, f in zip(jax.tree_util.tree_leaves(out[name]),
                           jax.tree_util.tree_leaves(st[name]),
                           jax.tree_util.tree_leaves(full[name])):
            o, s, f = np.asarray(o), np.asarray(s), np.asarray(f)
            np.testing.assert_array_equal(np.take(o, 1, axis=ax),
                                          np.take(s, 1, axis=ax))
            np.testing.assert_array_equal(np.take(o, 0, axis=ax),
                                          np.take(f, 0, axis=ax))
            np.testing.assert_array_equal(np.take(o, 2, axis=ax),
                                          np.take(f, 2, axis=ax))
    # the flush actually did something: memory written, caches cleared
    A0 = np.asarray(st["pattern"][0]["A"][:, 0])
    A1 = np.asarray(out["pattern"][0]["A"][:, 0])
    assert not np.array_equal(A0, A1)
    assert np.asarray(out["pattern"][0]["k"][:, 0]).max() == 0


def test_mask_decode_state_merges_rowwise(setup):
    cfg, params = setup
    a = decode_state_init(cfg, 2, serve_mode="armt", max_len=32,
                          dtype=jnp.float32, per_slot_pos=True)
    b = jax.tree_util.tree_map(lambda x: x + 1, a)
    m = jnp.array([True, False])
    out = mask_decode_state(m, b, a)
    np.testing.assert_array_equal(np.asarray(out["pos"]), [1, 0])
    for leaf in jax.tree_util.tree_leaves(out["prelude"]):
        leaf = np.asarray(leaf)                       # batch on axis 0
        assert leaf[0].min() == 1 and leaf[1].max() == 0
    for leaf in jax.tree_util.tree_leaves(out["pattern"]):
        leaf = np.asarray(leaf)                       # batch on axis 1
        assert leaf[:, 0].min() == 1 and leaf[:, 1].max() == 0


def test_scheduler_streaming_order_and_slot_reuse(setup):
    """Events stream in index order per request; slots are reused (more
    requests than slots) and every request completes exactly once."""
    cfg, params = setup
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=128)
    reqs = _requests(cfg, [5, 9, 17, 33, 21, 8, 12], 4, seed=7)
    sched = ContinuousScheduler(eng, n_slots=2, chunk=3)
    events = list(sched.run(reqs))
    assert all(isinstance(e, StreamEvent) for e in events)
    outs, done = _collect(events)
    assert len(done) == len(reqs)
    assert all(len(v) == 4 for v in outs.values())
