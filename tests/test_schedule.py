"""Diagonal schedule: Lemma 3.1 + DAG validity (property-based)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test extra ([test] in pyproject)
from hypothesis import given, settings, strategies as st

from repro.core import (StackLayout, cell_dependencies, diagonal_groups,
                        is_minimal, validate_schedule)


@given(st.integers(1, 40), st.integers(1, 40))
@settings(max_examples=30, deadline=None)
def test_lemma_3_1(S, L):
    groups = diagonal_groups(S, L)
    validate_schedule(groups, S, L)          # covers grid, respects deps
    assert is_minimal(groups, S, L)          # S+L-1 groups, earliest slots
    assert len(groups) == S + L - 1
    # group width is bounded by min(S, L) — at most N_layers concurrent ops
    assert max(len(g) for g in groups) == min(S, L)


def test_sequential_schedule_is_not_minimal():
    # the baseline executes S*L singleton groups
    S, L = 4, 3
    seq = [[(s, l)] for s in range(S) for l in range(L)]
    validate_schedule(seq, S, L)
    assert not is_minimal(seq, S, L)
    assert len(seq) == S * L > S + L - 1


def test_dependencies():
    assert cell_dependencies(0, 0) == []
    assert cell_dependencies(2, 0) == [(1, 0)]
    assert set(cell_dependencies(2, 3)) == {(2, 2), (1, 3)}


def test_stack_layout_slots():
    lay = StackLayout(prelude=("a",), pattern=("x", "y"), n_super=3)
    assert lay.n_layers == 7
    assert lay.layer_types == ("a", "x", "y", "x", "y", "x", "y")
    assert list(lay.position_slots(0)) == [1, 3, 5]
    assert list(lay.position_slots(1)) == [2, 4, 6]


def test_stack_layout_from_config():
    from repro.configs import get_config
    cfg = get_config("jamba-1.5-large-398b")
    lay = StackLayout.from_config(cfg)
    assert lay.n_layers == 72
    types = lay.layer_types
    assert sum(t == "attn" for t in types) == 9          # 1:7 attn:mamba
    assert sum(t.startswith("mamba") for t in types) == 63
    assert sum(t.endswith("moe") for t in types) == 36   # MoE every other
