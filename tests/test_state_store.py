"""Serving state store (serve/state_store.py, DESIGN.md §9): boundary
snapshot capture, segment-granular prefix caching (token-identical greedy
across boundary phases, collision-safe, LRU byte budget, disk spill),
multi-turn session resume (== one long concatenated generate), power-of-two
prompt bucketing, structured scheduler errors, and serving metrics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.memory import recurrent_state
from repro.models import forward_hidden, init_params
from repro.serve import (PrefixCache, Request, RequestError, ServeEngine,
                         SessionEvicted, SessionStore, StreamEvent,
                         prefix_hash_chain)
from repro.serve.engine import _pow2_chunks


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("h2o-danube-1.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def base_eng(setup):
    cfg, params = setup
    return ServeEngine(params, cfg, serve_mode="armt", max_len=256)


def _toks(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(8, cfg.vocab, (n,)).astype(np.int32)


def _leaves_close(a, b, **kw):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


# ---------------------------------------------------------------------------
# Capture path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", ["diagonal", "sequential"])
def test_boundary_capture_matches_prefix_forward(setup, schedule):
    """Snapshot at boundary c (assembled from the executor's per-step
    capture — for the diagonal schedule that means re-indexing the drain's
    staggered emissions) == final state of a fresh forward over the first
    c segments."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    S = 4
    toks = jnp.asarray(_toks(cfg, S * seg, seed=11)[None])
    _, fin, cap = forward_hidden(params, cfg, toks, schedule=schedule,
                                 capture_states=True)
    for c in (1, 3, S):
        _, fin_c = forward_hidden(params, cfg, toks[:, :c * seg],
                                  schedule=schedule)
        got = jax.tree_util.tree_map(lambda a, _c=c: a[_c - 1], cap)
        _leaves_close(recurrent_state(fin_c), got, atol=1e-6, rtol=1e-6)
    # boundary S == the run's own final state
    _leaves_close(recurrent_state(fin),
                  jax.tree_util.tree_map(lambda a: a[S - 1], cap),
                  atol=0, rtol=0)


# ---------------------------------------------------------------------------
# Prefix cache
# ---------------------------------------------------------------------------

def test_prefix_cache_hit_token_identical(setup, base_eng):
    """Acceptance: shared-prefix admissions with >=1 cached segment are
    token-identical (greedy) to the uncached engine across tail phases —
    empty tail (exact full-prefix hit: zero forward work), one token,
    one-short-of-boundary, and past-the-next-boundary."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    cache = PrefixCache(seg)
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      prefix_cache=cache)
    shared = _toks(cfg, 3 * seg, seed=1)
    cold = eng.generate(jnp.asarray(shared[None]), 4)      # fills the cache
    assert cold.cached_segments == 0
    assert (cold.tokens ==
            base_eng.generate(jnp.asarray(shared[None]), 4).tokens).all()
    for i, tail_len in enumerate((0, 1, seg - 1, seg + 3)):
        prompt = np.concatenate([shared, _toks(cfg, tail_len, seed=20 + i)])
        hit = eng.generate(jnp.asarray(prompt[None]), 4)
        ref = base_eng.generate(jnp.asarray(prompt[None]), 4)
        assert (hit.tokens == ref.tokens).all(), f"tail={tail_len}"
        assert hit.cached_segments == 3, f"tail={tail_len}"
    assert cache.stats.hits >= 4


def test_prefix_cache_longest_match_wins(setup, base_eng):
    """A prompt sharing only a shorter prefix matches the shorter boundary;
    growing the cache then upgrades the match."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    cache = PrefixCache(seg)
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      prefix_cache=cache)
    a = _toks(cfg, 4 * seg, seed=2)
    eng.generate(jnp.asarray(a[None]), 2)        # boundaries 1..4 cached
    b = np.concatenate([a[:2 * seg], _toks(cfg, 2 * seg, seed=3)])
    r = eng.generate(jnp.asarray(b[None]), 4)
    assert r.cached_segments == 2                # diverges after segment 2
    assert (r.tokens ==
            base_eng.generate(jnp.asarray(b[None]), 4).tokens).all()
    r2 = eng.generate(jnp.asarray(b[None]), 4)   # b's own boundaries now in
    assert r2.cached_segments == 4
    assert (r2.tokens == r.tokens).all()


def test_hash_collision_full_verification(setup):
    """A forged hash collision must not transplant a different prefix's
    state: match verifies full token ids and falls through."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    cache = PrefixCache(seg)
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      prefix_cache=cache)
    a = _toks(cfg, 2 * seg, seed=4)
    eng.generate(jnp.asarray(a[None]), 2)
    other = _toks(cfg, 2 * seg, seed=5)
    # forge: rekey a's 2-segment entry under other's 2-segment digest
    key_a = prefix_hash_chain(a, seg)[-1]
    key_other = prefix_hash_chain(other, seg)[-1]
    lru = cache._lru
    lru.entries[key_other] = lru.entries.pop(key_a)
    before = cache.stats.collisions
    n, snap = cache.match(other)
    assert n == 0 and snap is None
    assert cache.stats.collisions > before


def test_lru_eviction_byte_budget(setup):
    """Entries are evicted oldest-first under the byte budget; a hit
    refreshes recency."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    probe = PrefixCache(seg)
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      prefix_cache=probe)
    eng.generate(jnp.asarray(_toks(cfg, seg, seed=6)[None]), 2)
    one = probe.stats.bytes_in_ram                 # bytes per 1 snapshot
    assert one > 0

    cache = PrefixCache(seg, max_bytes=3 * one + one // 2)
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      prefix_cache=cache)
    prompts = [_toks(cfg, seg, seed=10 + i) for i in range(3)]
    for p in prompts:
        eng.generate(jnp.asarray(p[None]), 2)
    assert len(cache) == 3 and cache.stats.evictions == 0
    assert cache.match(prompts[0])[0] == 1         # touch: now most-recent
    eng.generate(jnp.asarray(_toks(cfg, seg, seed=13)[None]), 2)
    assert cache.stats.evictions == 1
    assert cache.stats.bytes_in_ram <= cache._lru.max_bytes
    assert cache.match(prompts[0])[0] == 1         # survivor (was touched)
    assert cache.match(prompts[1])[0] == 0         # LRU victim


def test_spill_to_disk_and_restore(setup, base_eng, tmp_path):
    """Evictions spill through CheckpointManager named blobs; a later hit
    restores the snapshot and still serves token-identical output."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    probe = PrefixCache(seg)
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      prefix_cache=probe)
    p0 = _toks(cfg, seg, seed=30)
    eng.generate(jnp.asarray(p0[None]), 2)
    one = probe.stats.bytes_in_ram

    cache = PrefixCache(seg, max_bytes=one + one // 2,
                        spill_dir=tmp_path / "spill")
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      prefix_cache=cache)
    p1 = _toks(cfg, seg, seed=31)
    eng.generate(jnp.asarray(p0[None]), 2)
    eng.generate(jnp.asarray(p1[None]), 2)         # evicts+spills p0's entry
    assert cache.stats.spills >= 1
    prompt = np.concatenate([p0, _toks(cfg, 3, seed=32)])
    hit = eng.generate(jnp.asarray(prompt[None]), 4)
    assert hit.cached_segments == 1
    assert cache.stats.restores >= 1
    ref = base_eng.generate(jnp.asarray(prompt[None]), 4)
    assert (hit.tokens == ref.tokens).all()


def test_rolling_hash_is_prefix_stable(setup):
    cfg, _ = setup
    a = _toks(cfg, 64, seed=7)
    b = np.concatenate([a, _toks(cfg, 32, seed=8)])
    ca, cb = prefix_hash_chain(a, 16), prefix_hash_chain(b, 16)
    assert cb[:len(ca)] == ca                      # chain extends, not rehashes
    assert len(set(cb)) == len(cb)


# ---------------------------------------------------------------------------
# Session store
# ---------------------------------------------------------------------------

def test_session_resume_matches_concatenated_generate(setup, base_eng):
    """Acceptance: a greedy multi-turn session (each turn feeds only its
    new tokens) is token-identical to re-prefilling the concatenated
    history, across in-segment and cross-segment turn boundaries."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    store = SessionStore()
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      session_store=store)
    turns = [_toks(cfg, seg + 5, seed=40), _toks(cfg, 7, seed=41),
             _toks(cfg, 2 * seg, seed=42)]
    history = np.empty(0, np.int32)
    for i, t in enumerate(turns):
        r = eng.generate(jnp.asarray(t[None]), 6, session_id="conv")
        assert r.resumed == (i > 0)
        ref = base_eng.generate(
            jnp.asarray(np.concatenate([history, t])[None]), 6)
        assert (r.tokens == ref.tokens).all(), f"turn {i}"
        history = np.concatenate([history, t, r.tokens[0]]).astype(np.int32)
    assert store.get("conv").tokens.shape[0] == history.shape[0]


def test_scheduler_session_resume(setup, base_eng):
    """Sessions through the continuous scheduler: the packed chunk freezes
    a finished slot's row bit-exactly, the row is lifted out at the chunk
    boundary, and the next turn (scheduler or single-shot generate) resumes
    token-identically."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    store = SessionStore()
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      session_store=store)
    p1, p2 = _toks(cfg, 2 * seg + 3, seed=50), _toks(cfg, 9, seed=51)

    def drive(reqs):
        outs = {}
        for ev in eng.serve(reqs, n_slots=2, chunk=3):
            assert isinstance(ev, StreamEvent), ev
            outs.setdefault(ev.req_id, []).append(ev.token)
        return outs

    o1 = drive([Request("t1", p1, 7, session_id="c"),
                Request("x", _toks(cfg, 5, seed=52), 4)])  # a co-batched req
    o2 = drive([Request("t2", p2, 7, session_id="c")])
    hist = np.concatenate([p1, np.asarray(o1["t1"], np.int32), p2])
    ref = base_eng.generate(jnp.asarray(hist[None]), 7)
    assert o2["t2"] == ref.tokens[0].tolist()
    # third turn via generate: scheduler-persisted state is interchangeable
    p3 = _toks(cfg, 4, seed=53)
    g = eng.generate(jnp.asarray(p3[None]), 4, session_id="c")
    hist = np.concatenate([hist, np.asarray(o2["t2"], np.int32), p3])
    assert (g.tokens ==
            base_eng.generate(jnp.asarray(hist[None]), 4).tokens).all()


def test_session_eviction_is_loud(setup):
    """An evicted (no-spill) session raises on generate and becomes a
    structured session_evicted event on the scheduler stream — never a
    silent fresh-context resume."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    store = SessionStore(max_bytes=1)              # evict everything
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      session_store=store)
    eng.generate(jnp.asarray(_toks(cfg, seg, seed=60)[None]), 3,
                 session_id="gone")
    assert store.stats.evictions == 1
    with pytest.raises(SessionEvicted):
        eng.generate(jnp.asarray(_toks(cfg, 4, seed=61)[None]), 3,
                     session_id="gone")
    evs = list(eng.serve([Request("r", _toks(cfg, 4, seed=62), 3,
                                  session_id="gone")], n_slots=1))
    assert [type(e) for e in evs] == [RequestError]
    assert evs[0].code == "session_evicted"
    # unknown session ids are NOT evicted ones: first turn just works
    evs = list(eng.serve([Request("r2", _toks(cfg, 4, seed=63), 3,
                                  session_id="fresh")], n_slots=1))
    assert sum(isinstance(e, StreamEvent) for e in evs) == 3


def test_session_spill_roundtrip(setup, base_eng, tmp_path):
    cfg, params = setup
    seg = cfg.armt.segment_len
    store = SessionStore(max_bytes=1, spill_dir=tmp_path / "sessions")
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      session_store=store)
    p1, p2 = _toks(cfg, seg + 2, seed=64), _toks(cfg, 5, seed=65)
    r1 = eng.generate(jnp.asarray(p1[None]), 4, session_id="s")
    assert store.stats.spills == 1                 # budget 1 byte: spilled
    r2 = eng.generate(jnp.asarray(p2[None]), 4, session_id="s")
    assert store.stats.restores == 1 and r2.resumed
    ref = base_eng.generate(
        jnp.asarray(np.concatenate([p1, r1.tokens[0], p2])[None]), 4)
    assert (r2.tokens == ref.tokens).all()


# ---------------------------------------------------------------------------
# Prompt bucketing (admission jit-cache bound)
# ---------------------------------------------------------------------------

def test_pow2_chunks():
    assert _pow2_chunks(13) == [8, 4, 1]
    assert _pow2_chunks(1) == [1]
    assert _pow2_chunks(16) == [16]
    for n in range(1, 70):
        parts = _pow2_chunks(n)
        assert sum(parts) == n
        assert all(p & (p - 1) == 0 for p in parts)
        assert parts == sorted(parts, reverse=True)


def test_bucketed_prefill_token_identical_and_bounded(setup):
    """Satellite acceptance: bucketed admission (the default) is
    token-identical (greedy) to the unbucketed path for every prompt
    length, and the number of compiled decode_step shapes stays
    logarithmic, not linear, in the lengths seen."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    bucketed = ServeEngine(params, cfg, serve_mode="armt", max_len=256)
    flat = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                       bucket_prompts=False)
    assert bucketed.bucket_prompts and not flat.bucket_prompts
    lens = [1, 3, seg - 1, seg, seg + 1, 2 * seg + 5, 3 * seg + seg // 2 + 1]
    for i, L in enumerate(lens):
        p = jnp.asarray(_toks(cfg, L, seed=70 + i)[None])
        a = bucketed.generate(p, 4)
        b = flat.generate(p, 4)
        assert (a.tokens == b.tokens).all(), f"len={L}"
    if hasattr(bucketed._step, "_cache_size"):
        # chunked-prefill shapes: powers of two <= seg plus the [B,1] decode
        # step — vs one compile per distinct tail length unbucketed
        n_pow2 = seg.bit_length()
        assert bucketed._step._cache_size() <= n_pow2 + 1


# ---------------------------------------------------------------------------
# Structured scheduler errors + serving metrics
# ---------------------------------------------------------------------------

def test_scheduler_structured_errors(setup):
    """Queue-full and invalid requests come back as in-band RequestError
    events; valid co-queued requests still complete. Free slots count as
    capacity: queue_full fires only when all slots are busy AND the
    backlog is at its limit."""
    cfg, params = setup
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256)
    bad_new = Request("bad_new", _toks(cfg, 5, seed=81), 0)
    ok = Request("ok", _toks(cfg, 5, seed=80), 3)
    bad_prompt = Request("bad_prompt", np.empty(0, np.int32), 3)
    ok2 = Request("ok2", _toks(cfg, 5, seed=82), 3)
    overflow = Request("overflow", _toks(cfg, 5, seed=83), 3)
    # 1 slot + queue of 2: bad_new rejected at admission (slot was free),
    # ok takes the slot, bad_prompt+ok2 queue, overflow exceeds capacity
    evs = list(eng.serve([bad_new, ok, bad_prompt, ok2, overflow],
                         n_slots=1, chunk=2, max_queue=2))
    errs = {e.req_id: e.code for e in evs if isinstance(e, RequestError)}
    assert errs == {"bad_new": "invalid_request",
                    "bad_prompt": "invalid_request",
                    "overflow": "queue_full"}
    toks = [e for e in evs if isinstance(e, StreamEvent)]
    assert [e.req_id for e in toks] == ["ok"] * 3 + ["ok2"] * 3
    assert toks[2].done and toks[-1].done
    # a queue-sized burst with a free slot is NOT queue_full: slots are
    # capacity too, so n_slots + max_queue requests all complete
    evs = list(eng.serve([Request(f"r{i}", _toks(cfg, 5, seed=84 + i), 2)
                          for i in range(3)], n_slots=1, chunk=2,
                         max_queue=2))
    assert not any(isinstance(e, RequestError) for e in evs)
    assert sum(e.done for e in evs if isinstance(e, StreamEvent)) == 3
    # session_id without a store on the engine is rejected, not crashed
    evs = list(eng.serve([Request("s", _toks(cfg, 5, seed=83), 2,
                                  session_id="nope")], n_slots=1))
    assert [type(e) for e in evs] == [RequestError]
    assert evs[0].code == "invalid_request"


def test_serving_metrics(setup):
    """GenerationResult and StreamEvent carry host-clock TTFT / tok/s."""
    cfg, params = setup
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256)
    r = eng.generate(jnp.asarray(_toks(cfg, 20, seed=90)[None]), 5)
    assert r.ttft_s > 0 and r.tok_s > 0
    first, last = None, None
    for ev in eng.serve([Request("m", _toks(cfg, 20, seed=91), 5)],
                        n_slots=1, chunk=2):
        first = first or ev
        last = ev
    assert first.ttft_s is not None and first.ttft_s > 0
    assert last.done and last.ttft_s == first.ttft_s
    assert last.tok_s is not None and last.tok_s > 0
