"""THE core invariant: run_diagonal == run_sequential exactly (pure
reordering, paper §3) — property-tested over stack shapes, including
heterogeneous patterns and preludes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test extra ([test] in pyproject)
from hypothesis import given, settings, strategies as st

from repro.core import StackLayout, run_diagonal, run_sequential


def _toy_apply(t, p, x, st):
    scale = {"a": 1.0, "b": 0.5, "c": 2.0}[t]
    y = jnp.tanh(x @ p["w"] * scale + st["m"][None, None, :])
    return y, {"m": st["m"] + y.mean((0, 1))}


def _build(layout, key, D):
    ks = jax.random.split(key, 1 + len(layout.pattern))
    params = {
        "prelude": tuple({"w": jax.random.normal(
            jax.random.fold_in(ks[0], j), (D, D)) * 0.4}
            for j in range(len(layout.prelude))),
        "pattern": tuple({"w": jax.random.normal(
            ks[1 + p], (layout.n_super, D, D)) * 0.4}
            for p in range(len(layout.pattern))),
    }
    state = {
        "prelude": tuple({"m": jnp.zeros(D)} for _ in layout.prelude),
        "pattern": tuple({"m": jnp.zeros((layout.n_super, D))}
                         for _ in layout.pattern),
    }
    return params, state


@given(
    st.integers(1, 6),                        # segments
    st.integers(1, 3),                        # n_super
    st.sampled_from([("a",), ("a", "b"), ("a", "b", "c"), ("b", "b")]),
    st.sampled_from([(), ("a",), ("c", "a")]),
)
@settings(max_examples=15, deadline=None)
def test_diagonal_equals_sequential(S, n_super, pattern, prelude):
    layout = StackLayout(prelude=prelude, pattern=pattern, n_super=n_super)
    B, T, D = 2, 3, 8
    params, state0 = _build(layout, jax.random.PRNGKey(S * 7 + n_super), D)
    segs = jax.random.normal(jax.random.PRNGKey(99), (S, B, T, D))
    ys_s, st_s = run_sequential(layout, params, state0, segs, _toy_apply)
    ys_d, st_d = run_diagonal(layout, params, state0, segs, _toy_apply)
    np.testing.assert_allclose(np.asarray(ys_s), np.asarray(ys_d),
                               atol=1e-6, rtol=1e-6)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                atol=1e-6, rtol=1e-6),
        st_s, st_d)


def test_gradients_flow_through_both():
    layout = StackLayout(prelude=(), pattern=("a", "b"), n_super=2)
    B, T, D, S = 1, 2, 4, 3
    params, state0 = _build(layout, jax.random.PRNGKey(0), D)
    segs = jax.random.normal(jax.random.PRNGKey(1), (S, B, T, D))

    def loss(params, run):
        ys, _ = run(layout, params, state0, segs, _toy_apply)
        return jnp.sum(ys ** 2)

    g_s = jax.grad(lambda p: loss(p, run_sequential))(params)
    g_d = jax.grad(lambda p: loss(p, run_diagonal))(params)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                atol=1e-5, rtol=1e-5),
        g_s, g_d)
    # gradients are nonzero for every layer
    flat = jax.tree_util.tree_leaves(g_d)
    assert all(float(jnp.abs(l).max()) > 0 for l in flat)


def test_remat_matches():
    layout = StackLayout(prelude=(), pattern=("a",), n_super=3)
    params, state0 = _build(layout, jax.random.PRNGKey(2), 4)
    segs = jax.random.normal(jax.random.PRNGKey(3), (4, 1, 2, 4))
    y1, _ = run_diagonal(layout, params, state0, segs, _toy_apply, remat=False)
    y2, _ = run_diagonal(layout, params, state0, segs, _toy_apply, remat=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)
