"""THE core invariant: run_diagonal == run_sequential exactly (pure
reordering, paper §3).

The deterministic parametrized grid below always runs (no optional deps) —
the suite used to guard this invariant only behind `importorskip
("hypothesis")`, which silently skipped it on minimal installs. The
hypothesis fuzz on top widens coverage when the `[test]` extra is
installed (CI installs it and fails if it is missing).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import StackLayout, run_diagonal, run_sequential


def _toy_apply(t, p, x, st):
    scale = {"a": 1.0, "b": 0.5, "c": 2.0}[t]
    y = jnp.tanh(x @ p["w"] * scale + st["m"][None, None, :])
    return y, {"m": st["m"] + y.mean((0, 1))}


def _build(layout, key, D):
    ks = jax.random.split(key, 1 + len(layout.pattern))
    params = {
        "prelude": tuple({"w": jax.random.normal(
            jax.random.fold_in(ks[0], j), (D, D)) * 0.4}
            for j in range(len(layout.prelude))),
        "pattern": tuple({"w": jax.random.normal(
            ks[1 + p], (layout.n_super, D, D)) * 0.4}
            for p in range(len(layout.pattern))),
    }
    state = {
        "prelude": tuple({"m": jnp.zeros(D)} for _ in layout.prelude),
        "pattern": tuple({"m": jnp.zeros((layout.n_super, D))}
                         for _ in layout.pattern),
    }
    return params, state


def _check_equal(layout, S):
    B, T, D = 2, 3, 8
    params, state0 = _build(layout, jax.random.PRNGKey(S * 7 + layout.n_super),
                            D)
    segs = jax.random.normal(jax.random.PRNGKey(99), (S, B, T, D))
    ys_s, st_s = run_sequential(layout, params, state0, segs, _toy_apply)
    ys_d, st_d = run_diagonal(layout, params, state0, segs, _toy_apply)
    np.testing.assert_allclose(np.asarray(ys_s), np.asarray(ys_d),
                               atol=1e-6, rtol=1e-6)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                atol=1e-6, rtol=1e-6),
        st_s, st_d)


# Deterministic coverage of the shape space: fewer segments than layers
# (mostly fill/drain), more segments than layers, heterogeneous patterns,
# repeated types, and preludes.
@pytest.mark.parametrize("S,n_super,pattern,prelude", [
    (1, 1, ("a",), ()),                       # single cell
    (2, 3, ("a",), ()),                       # S < L (fill/drain dominated)
    (6, 1, ("a", "b"), ()),                   # S > L, heterogeneous pattern
    (4, 2, ("a", "b", "c"), ()),              # 3-type pattern
    (3, 2, ("b", "b"), ("a",)),               # repeated type + prelude
    (5, 3, ("a", "b"), ("c", "a")),           # deep stack + 2-layer prelude
])
def test_diagonal_equals_sequential(S, n_super, pattern, prelude):
    layout = StackLayout(prelude=prelude, pattern=pattern, n_super=n_super)
    _check_equal(layout, S)


def test_diagonal_equals_sequential_fuzz():
    """Hypothesis widening of the deterministic grid (test extra)."""
    hyp = pytest.importorskip("hypothesis")  # [test] extra in pyproject
    from hypothesis import given, settings, strategies as st

    @given(
        st.integers(1, 6),                    # segments
        st.integers(1, 3),                    # n_super
        st.sampled_from([("a",), ("a", "b"), ("a", "b", "c"), ("b", "b")]),
        st.sampled_from([(), ("a",), ("c", "a")]),
    )
    @settings(max_examples=15, deadline=None)
    def fuzz(S, n_super, pattern, prelude):
        layout = StackLayout(prelude=prelude, pattern=pattern,
                             n_super=n_super)
        _check_equal(layout, S)

    fuzz()


def test_gradients_flow_through_both():
    layout = StackLayout(prelude=(), pattern=("a", "b"), n_super=2)
    B, T, D, S = 1, 2, 4, 3
    params, state0 = _build(layout, jax.random.PRNGKey(0), D)
    segs = jax.random.normal(jax.random.PRNGKey(1), (S, B, T, D))

    def loss(params, run):
        ys, _ = run(layout, params, state0, segs, _toy_apply)
        return jnp.sum(ys ** 2)

    g_s = jax.grad(lambda p: loss(p, run_sequential))(params)
    g_d = jax.grad(lambda p: loss(p, run_diagonal))(params)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                atol=1e-5, rtol=1e-5),
        g_s, g_d)
    # gradients are nonzero for every layer
    flat = jax.tree_util.tree_leaves(g_d)
    assert all(float(jnp.abs(l).max()) > 0 for l in flat)


def test_remat_matches():
    layout = StackLayout(prelude=(), pattern=("a",), n_super=3)
    params, state0 = _build(layout, jax.random.PRNGKey(2), 4)
    segs = jax.random.normal(jax.random.PRNGKey(3), (4, 1, 2, 4))
    y1, _ = run_diagonal(layout, params, state0, segs, _toy_apply, remat=False)
    y2, _ = run_diagonal(layout, params, state0, segs, _toy_apply, remat=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


def test_padded_slots_do_not_poison_group_coupled_apply():
    """Regression: invalid fill/drain slots used to be cleared with
    ``buf * valid`` — a block that emits inf/NaN on empty padding (as a
    fused-kernel epilogue or a global MoE router does) then left
    ``0 * inf = nan`` in the buffer, which poisons any group-coupled
    application on the next step. With the jnp.where clear, padding enters
    every grouped application as exact zeros and outputs stay finite."""
    layout = StackLayout(prelude=(), pattern=("a",), n_super=3)   # L = 3
    S, B, T, D = 4, 2, 3, 8
    params, state0 = _build(layout, jax.random.PRNGKey(5), D)
    segs = jax.random.normal(jax.random.PRNGKey(6), (S, B, T, D))

    def seeded_apply(t, p, x, st):
        y, new = _toy_apply(t, p, x, st)
        # a kernel fed an all-zero padded slot emits -inf (e.g. log/softmax
        # of an empty row)
        empty = jnp.abs(x).sum() == 0
        return jnp.where(empty, -jnp.inf, y), new

    def grouped_apply(t, pp, x, ss):
        # per-slot math ...
        y, st = jax.vmap(lambda p, xx, s: seeded_apply(t, p, xx, s))(
            pp, x, ss)
        # ... plus a group-coupled epilogue statistic over the WHOLE group
        # input (the shape of a global MoE router / grouped attention
        # normalizer): one NaN slot poisons every slot
        return y / (1.0 + jnp.abs(x).mean()), st

    ys, fin = run_diagonal(layout, params, state0, segs, seeded_apply,
                           grouped_apply=grouped_apply)
    assert bool(jnp.isfinite(ys).all()), "padded slots leaked inf/nan"
    for leaf in jax.tree_util.tree_leaves(fin):
        assert bool(jnp.isfinite(leaf).all())
