"""Bounded-memory streaming prefill (DESIGN.md §15, ROADMAP
"million-token workloads with bounded memory").

Two properties, asserted separately:

* **Flatness** — the compiled streaming prefill stepper's temp bytes must
  not grow with the number of admitted segments: the stream carry replaces
  the ``[S, B, T, D]`` ``ys`` with a rolling ``min(L, S)``-segment window
  plus one retained row per segment, so S only enters through ``xs`` (an
  *argument*, not a temp). Measured via ``memory_analysis()`` on the AOT
  compile, the same instrumentation the admission controller uses
  (``ServeEngine.prefill_memory_stats``).

* **Exactness** — streaming is a pure change of what is *retained*, never
  of what is computed: retained rows, window contents, final recurrent
  state, and captured boundary snapshots are bitwise identical to the
  full-ys run. The reference is the full-width driver
  (``band_skip=False``): the banded fused driver computes over
  band-sliced groups, which is a (pre-existing, documented) ulp-level
  fusion difference orthogonal to streaming, and stream mode always runs
  the full-width body.

The 8-fake-device mesh check (stream vs full bit-identity under GSPMD with
``pipeline_carry_specs`` placing win/brow) runs in a slow-marked
subprocess like tests/test_serve_sharded.py.
"""
import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARMTConfig, get_smoke_config
from repro.core import diagonal as diag
from repro.core.schedule import StackLayout
from repro.models import init_params
from repro.models.blocks import make_apply_block
from repro.models.grouped_blocks import resolve_grouped_apply
from repro.models.model import embed_segments, init_state


def _cfg(**kw):
    base = dataclasses.replace(
        get_smoke_config("llama-1b-armt"), n_layers=4, d_model=32, n_heads=2,
        n_kv_heads=2, d_head=16, d_ff=64, max_position=4096, dtype="float32",
        armt=ARMTConfig(segment_len=16, num_mem_tokens=4, d_mem=8))
    return dataclasses.replace(base, **kw) if kw else base


def _setup(cfg, S, B, seed=0):
    params = init_params(cfg, jax.random.PRNGKey(seed))
    layout = StackLayout.from_config(cfg)
    seg = cfg.armt.segment_len
    tokens = jax.random.randint(jax.random.PRNGKey(seed + 1), (B, S * seg),
                                0, cfg.vocab)
    segs = embed_segments(params, cfg, tokens, seg, True)
    state0 = init_state(cfg, B, "segmented", segs.dtype)
    exec_params = {"prelude": params.get("prelude", ()),
                   "pattern": params["pattern"]}
    return params, layout, segs, state0, exec_params


def _assert_stream_matches_full(stream_out, full_out, capture):
    if capture:
        sdict, sstate, scap = stream_out
        ys, fstate, fcap = full_out
    else:
        (sdict, sstate), (ys, fstate) = stream_out, full_out
        scap = fcap = None
    S = ys.shape[0]
    brow, win = sdict["brow"], sdict["win"]
    assert brow.shape == (S,) + ys.shape[1:2] + ys.shape[3:]
    assert (brow == ys[:, :, -1]).all()
    W = win.shape[0]
    assert W == min(S, 4)               # min(L, S) with L = n_layers = 4
    for s in range(S - W, S):
        assert (win[s % W] == ys[s]).all(), s
    for a, b in zip(jax.tree_util.tree_leaves(sstate),
                    jax.tree_util.tree_leaves(fstate)):
        assert (a == b).all()
    if capture:
        la, lb = (jax.tree_util.tree_leaves(scap),
                  jax.tree_util.tree_leaves(fcap))
        assert len(la) == len(lb) and all(
            (a == b).all() for a, b in zip(la, lb))


@pytest.mark.parametrize("capture", [False, True])
@pytest.mark.parametrize("grouped", ["vmap", "fused"])
def test_run_diagonal_stream_bitwise(grouped, capture):
    """One-shot run_diagonal: stream retained outputs / state / captures
    are bitwise equal to the full-width full-ys run."""
    cfg = _cfg()
    _, layout, segs, state0, exec_params = _setup(cfg, S=6, B=2)
    apply = make_apply_block(cfg, mode="segmented", ssm_method="assoc")
    ga = resolve_grouped_apply(cfg, grouped, mode="segmented",
                               ssm_method="assoc")
    kw = dict(grouped_apply=ga, capture_states=capture)
    full = diag.run_diagonal(layout, exec_params, state0, segs, apply,
                             band_skip=False, **kw)
    stream = diag.run_diagonal(layout, exec_params, state0, segs, apply,
                               stream_ys=True, **kw)
    _assert_stream_matches_full(stream, full, capture)


@pytest.mark.parametrize("capture", [False, True])
def test_run_diagonal_stream_bitwise_multi_position(capture):
    """Same property on a 2-position pattern schedule (pattern length 2,
    2 superblocks) so the grouped fused launch spans multiple slots."""
    cfg = _cfg(block_pattern=("attn", "attn"))   # n_superblocks derives to 2
    _, layout, segs, state0, exec_params = _setup(cfg, S=5, B=1)
    apply = make_apply_block(cfg, mode="segmented", ssm_method="assoc")
    ga = resolve_grouped_apply(cfg, "fused", mode="segmented",
                               ssm_method="assoc")
    full = diag.run_diagonal(layout, exec_params, state0, segs, apply,
                             grouped_apply=ga, capture_states=capture,
                             band_skip=False)
    stream = diag.run_diagonal(layout, exec_params, state0, segs, apply,
                               grouped_apply=ga, capture_states=capture,
                               stream_ys=True)
    _assert_stream_matches_full(stream, full, capture)


@pytest.mark.parametrize("capture", [False, True])
@pytest.mark.parametrize("chunks", [(11,), (4, 4, 3), (1,) * 11])
def test_pipeline_stream_bitwise(chunks, capture):
    """Resumable pipeline: any chunking of the S+L-1 anti-diagonal groups
    finalizes to the same (bitwise) stream outputs as the one-shot run and
    the full-ys pipeline."""
    cfg = _cfg()
    S, B = 8, 1
    _, layout, segs, state0, exec_params = _setup(cfg, S, B)
    apply = make_apply_block(cfg, mode="segmented", ssm_method="assoc")
    assert sum(chunks) == S + 4 - 1

    def drive(stream):
        xs, carry = diag.pipeline_init(layout, state0, segs,
                                       capture_states=capture,
                                       stream_ys=stream)
        for n in chunks:
            carry = diag.pipeline_step(layout, exec_params, xs, carry, apply,
                                       n_groups=n)
        return diag.pipeline_finalize(layout, carry)

    ys, fstate, fcap = drive(False)
    sdict, sstate, scap = drive(True)
    full = (ys, fstate, fcap) if capture else (ys, fstate)
    stream = (sdict, sstate, scap) if capture else (sdict, sstate)
    _assert_stream_matches_full(stream, full, capture)
    one_shot = diag.run_diagonal(layout, exec_params, state0, segs, apply,
                                 stream_ys=True, capture_states=capture)
    sd2 = one_shot[0]
    assert (sd2["brow"] == sdict["brow"]).all()
    assert (sd2["win"] == sdict["win"]).all()


def test_engine_stream_prefill_bitwise():
    """ServeEngine.start_prefill(stream=True): logits / state / position
    bitwise identical to the full-ys pipeline, including staged admission
    under max_stage_segments (the overflow path)."""
    from repro.serve.engine import ServeEngine

    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, size=16 * 9 + 5).astype(np.int32)

    def drive(**kw):
        pipe = eng.start_prefill(prompt[None], groups_per_call=3, **kw)
        while not pipe.advance():
            pass
        return pipe.result()

    ref = drive(stream=False)
    for kw in (dict(stream=True), dict(stream=True, max_stage_segments=4)):
        got = drive(**kw)
        assert (np.asarray(got[0]) == np.asarray(ref[0])).all(), kw
        for a, b in zip(jax.tree_util.tree_leaves(got[1]),
                        jax.tree_util.tree_leaves(ref[1])):
            assert (np.asarray(a) == np.asarray(b)).all(), kw
        assert got[2] == ref[2], kw


def test_stream_temp_bytes_flat_in_segments():
    """Tier-1 flatness: the streaming prefill stepper's compiled temp bytes
    are independent of n_segments — S=64 within 1.1x of S=8 (on this CPU
    lowering they are exactly equal; 1.1x is the acceptance bound)."""
    from repro.serve.engine import ServeEngine

    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg)
    s8 = eng.prefill_memory_stats(8, stream=True)
    s64 = eng.prefill_memory_stats(64, stream=True)
    assert s8["temp_bytes"] and s64["temp_bytes"]
    assert s64["temp_bytes"] <= 1.1 * s8["temp_bytes"], (s8, s64)
    # the stream carry itself is also flat: output bytes grow only by the
    # retained rows (S * B * D), not by S * B * T * D
    full64 = eng.prefill_memory_stats(64, stream=False)
    assert s64["output_bytes"] < full64["output_bytes"], (s64, full64)


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import dataclasses
import numpy as np
import jax
jax.config.update("jax_default_matmul_precision", "highest")
from repro.configs import ARMTConfig, get_smoke_config
from repro.models import init_params
from repro.serve.engine import ServeEngine
from repro.launch.mesh import parse_mesh

cfg = dataclasses.replace(
    get_smoke_config("llama-1b-armt"), n_layers=4, d_model=32, n_heads=4,
    n_kv_heads=4, d_head=8, d_ff=64, max_position=4096, dtype="float32",
    armt=ARMTConfig(segment_len=16, num_mem_tokens=4, d_mem=8))
params = init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(5)
prompt = rng.integers(0, cfg.vocab, size=16 * 7 + 2).astype(np.int32)

def drive(eng, **kw):
    pipe = eng.start_prefill(prompt[None], groups_per_call=2, **kw)
    while not pipe.advance():
        pass
    return pipe.result()

for name, spec in (("data", "data=2,model=4"), ("stage", "stage=2,model=4")):
    eng = ServeEngine(params, cfg, mesh=parse_mesh(spec))
    full = drive(eng, stream=False)
    for kw in (dict(stream=True), dict(stream=True, max_stage_segments=4)):
        got = drive(eng, **kw)
        assert (np.asarray(got[0]) == np.asarray(full[0])).all(), (name, kw)
        for a, b in zip(jax.tree_util.tree_leaves(got[1]),
                        jax.tree_util.tree_leaves(full[1])):
            assert (np.asarray(a) == np.asarray(b)).all(), (name, kw)
        assert got[2] == full[2], (name, kw)
    print(f"OK mesh_{name}")
"""

_MESH_MARKERS = ("mesh_data", "mesh_stage")


@pytest.mark.slow
def test_stream_prefill_bitwise_on_mesh():
    """Stream vs full prefill is bit-identical under GSPMD on 8 fake
    devices (data- and stage-sharded meshes), exercising the win/brow
    entries of parallel.sharding.pipeline_carry_specs."""
    try:
        r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                           capture_output=True, text=True, timeout=600,
                           env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                "HOME": "/root"})
    except subprocess.TimeoutExpired:
        pytest.skip("mesh stream-prefill subprocess exceeded 600s: "
                    "environment too constrained to compile the "
                    "8-fake-device GSPMD programs")
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-2000:])
    for m in _MESH_MARKERS:
        assert f"OK {m}" in r.stdout, (m, r.stdout[-1000:])
