"""Pooled concurrent admissions (DESIGN.md §12): N in-flight prefill
carries advance as one global (request, segment, layer) diagonal grid
unified with decode. Covers the core pooled stepper (bit-exact vs
per-carry stepping at heterogeneous cursors, pads are no-ops), token
identity vs the blocking path across N / fairness policies / mixed
admission phases, round-robin no-starvation under a burst, the carry-pool
donation/aliasing regression, the idle-drain tight loop, and an
8-fake-device mesh parity subprocess (slow-marked)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import diagonal as D
from repro.core.schedule import (StackLayout, cells_completed, group_size,
                                 groups_remaining, n_diagonal_groups,
                                 pool_cells_remaining)
from repro.models import init_params, init_state
from repro.models.blocks import make_apply_block
from repro.serve import (AdmissionPool, ContinuousScheduler, PrefixCache,
                         Request, ServeEngine, StreamEvent)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("h2o-danube-1.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _toks(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(8, cfg.vocab, (n,)).astype(np.int32)


def _requests(cfg, lens, max_new, seed=0):
    return [Request(req_id=f"r{i}", prompt=_toks(cfg, L, seed=seed + i),
                    max_new=max_new)
            for i, L in enumerate(lens)]


def _collect(events):
    outs = {}
    for ev in events:
        assert isinstance(ev, StreamEvent), ev
        outs.setdefault(ev.req_id, []).append(ev.token)
    return outs


def _leaf_ptrs(tree):
    return {l.unsafe_buffer_pointer()
            for l in jax.tree_util.tree_leaves(tree)
            if isinstance(l, jax.Array)}


# ---------------------------------------------------------------------------
# Core: the pooled stepper is bit-exact at heterogeneous cursors
# ---------------------------------------------------------------------------

def test_pool_stepper_matches_single_stepper(setup):
    """pipeline_step_pool == one pipeline_step per member (to float32
    epsilon — vmap batches the matmuls, which reassociates the
    reductions; greedy-token identity is asserted at the serve level),
    with members at DIFFERENT cursors (one fresh, one mid-grid, one
    overshot) plus a pow2 pad entry — and the pad stays an all-zero
    no-op while its cursor churns past the grid."""
    cfg, params = setup
    layout = StackLayout.from_config(cfg)
    apply = make_apply_block(cfg, mode="segmented", ssm_method="assoc")
    ep = {"prelude": params["prelude"], "pattern": params["pattern"]}
    S, B = 3, 1
    T = cfg.armt.segment_len + cfg.armt.num_mem_tokens
    n_steps = n_diagonal_groups(S, layout.n_layers)
    st0 = init_state(cfg, B, "segmented", jnp.float32)

    members = []
    for i, pre_steps in enumerate((0, 2, n_steps)):   # fresh / mid / overshot
        segs = jax.random.normal(jax.random.PRNGKey(10 + i),
                                 (S, B, T, cfg.d_model))
        xs, carry = D.pipeline_init(layout, st0, segs, capture_states=True)
        if pre_steps:
            carry = D.pipeline_step(layout, ep, xs, carry, apply,
                                    n_groups=pre_steps)
        members.append((xs, carry))
    pad = D.pipeline_pool_pad(members[0][0], members[0][1], n_steps)
    members.append(pad)

    k = 2
    refs = [D.pipeline_step(layout, ep, xs, carry, apply, n_groups=k)
            for xs, carry in members[:3]]
    xs_pool = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls),
                                     *[m[0] for m in members])
    carry_pool = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls),
                                        *[m[1] for m in members])
    out = D.pipeline_step_pool(layout, ep, xs_pool, carry_pool, apply,
                               n_groups=k)
    for i, ref in enumerate(refs):
        got = jax.tree_util.tree_map(lambda a, _i=i: a[_i], out)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-5)
    # the pad member: cursor advanced (fixed-shape scan) but every masked
    # no-op left its buffers zero
    pad_out = jax.tree_util.tree_map(lambda a: a[3], out)
    assert int(pad_out["step"]) == n_steps + k
    for key in ("buf", "ys", "cap"):
        for leaf in jax.tree_util.tree_leaves(pad_out[key]):
            assert not np.asarray(leaf).any(), key


def test_global_grid_cursors():
    """Host-side bookkeeping of the global (request, segment, layer) grid:
    per-group cell counts, the saturating completed-cells cursor, and the
    pool-level remaining-cells sum."""
    S, L = 4, 3
    n = n_diagonal_groups(S, L)
    assert [group_size(i, S, L) for i in range(n)] == [1, 2, 3, 3, 2, 1]
    assert sum(group_size(i, S, L) for i in range(n)) == S * L
    assert cells_completed(0, S, L) == 0
    assert cells_completed(2, S, L) == 3
    assert cells_completed(n, S, L) == S * L
    assert cells_completed(n + 5, S, L) == S * L      # overshoot saturates
    assert [groups_remaining(i, S, L) for i in (0, 2, n, n + 5)] == \
        [n, n - 2, 0, 0]
    # a pool of three carries: fresh (4 segs), mid-grid (2 segs, 1 group
    # in), exhausted (1 seg, overshot)
    assert pool_cells_remaining([0, 1, 99], [4, 2, 1], L) == \
        (4 * L) + (2 * L - cells_completed(1, 2, L)) + 0


# ---------------------------------------------------------------------------
# Token identity: pooled concurrent admissions vs blocking
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_conc", [2, 3, None])   # None = free-slot-bounded
def test_concurrent_token_identity(setup, n_conc):
    """Acceptance: N concurrent pooled admissions == blocking admission ==
    single-request generate, token for token, across mixed admission
    phases (mid-segment / boundary / tail-only prompts, more requests
    than slots so admissions overlap decode)."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256)
    lens = [2 * seg, 2 * seg + 1, seg - 1, 13, 3 * seg + seg // 2, 4 * seg]
    max_new = 6
    reqs = _requests(cfg, lens, max_new)
    blocking = _collect(eng.serve(list(reqs), n_slots=3, chunk=4,
                                  prefill_groups_per_chunk=0))
    got = _collect(eng.serve(list(reqs), n_slots=3, chunk=4,
                             prefill_groups_per_chunk=2,
                             max_concurrent_admissions=n_conc))
    assert got == blocking
    for r in reqs:
        ref = eng.generate(jnp.asarray(r.prompt)[None], max_new).tokens[0]
        assert got[r.req_id] == ref.tolist(), r.req_id


@pytest.mark.parametrize("kw", [
    dict(fused_admission=True, max_concurrent_admissions=3),
    dict(fused_admission=True),                    # free-slot-bounded pool
    dict(admission_fairness="oldest_first"),
    dict(prefill_groups_per_chunk=-1),             # whole-stage pooled units
])
def test_concurrent_modes_token_identity(setup, kw):
    """The fused global-grid launch, the head-of-line fairness policy, and
    whole-stage group budgets all stay token-identical to blocking with a
    pool of concurrent admissions in flight."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256)
    lens = [2 * seg, 2 * seg, seg + 3, 3 * seg + 5, 9]
    reqs = _requests(cfg, lens, 6, seed=200)
    blocking = _collect(eng.serve(list(reqs), n_slots=3, chunk=4,
                                  prefill_groups_per_chunk=0))
    kw.setdefault("prefill_groups_per_chunk", 2)
    got = _collect(eng.serve(list(reqs), n_slots=3, chunk=4, **kw))
    assert got == blocking, kw


def test_concurrent_prefix_cache_identity(setup):
    """Concurrent admissions sharing a cached prefix stay token-identical
    to blocking. Cache HITS legitimately differ: members admitted into the
    pool together race the first member's insert (blocking serializes, so
    every follower hits), but a request admitted after the pool drains
    still hits the freshly inserted prefix."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    sys_p = _toks(cfg, 2 * seg, seed=300)
    prompts = [np.concatenate([sys_p, _toks(cfg, seg + 3, seed=301 + i)])
               for i in range(4)]
    stats, outs = {}, {}
    for mode, kw in (("blocking", dict(prefill_groups_per_chunk=0)),
                     ("pooled", dict(prefill_groups_per_chunk=2,
                                     max_concurrent_admissions=3))):
        cache = PrefixCache(seg, max_bytes=64 << 20)
        eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                          prefix_cache=cache)
        reqs = [Request(f"p{i}", p, 5) for i, p in enumerate(prompts)]
        outs[mode] = _collect(eng.serve(reqs, n_slots=3, chunk=3, **kw))
        st = cache.stats.as_dict()
        stats[mode] = (st["hits"], st["insertions"], st["collisions"])
    assert outs["pooled"] == outs["blocking"]
    assert stats["blocking"][0] == 3        # p1..p3 all hit behind p0
    assert stats["pooled"][0] >= 1          # p3 (post-pool) hits at least
    assert stats["pooled"][2] == stats["blocking"][2] == 0   # no collisions


# ---------------------------------------------------------------------------
# Fairness / no-starvation and the queue-wait metric
# ---------------------------------------------------------------------------

def test_round_robin_no_starvation_under_burst(setup):
    """A burst of long prompts with pool headroom: every burst member is
    admitted immediately (queue wait ~ 0, concurrency reported on its
    events) and completes; with the pool capped at 1 the same burst
    serializes — later members queue for whole admissions, so the summed
    queue wait is strictly larger. That gap is the metric the pooled
    scheduler attacks."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256)

    def burst():
        return ([Request("steady", _toks(cfg, 5, seed=400), 30)]
                + [Request(f"L{i}", _toks(cfg, 4 * seg, seed=401 + i), 3)
                   for i in range(4)])

    waits = {}
    for mode, n_conc in (("pooled", None), ("serial", 1)):
        sched = ContinuousScheduler(eng, n_slots=5, chunk=4, max_queue=8,
                                    prefill_groups_per_chunk=2,
                                    max_concurrent_admissions=n_conc)
        done = {e.req_id: e for e in sched.run(burst())
                if isinstance(e, StreamEvent) and e.done}
        assert set(done) == {"steady", "L0", "L1", "L2", "L3"}
        assert len(sched.admission_windows) == 5
        waits[mode] = sum(done[f"L{i}"].queue_wait_s for i in range(4))
        conc = [done[f"L{i}"].concurrent_admissions for i in range(4)]
        if mode == "pooled":
            # all four longs (plus the steady admission) were in flight
            # together; none starved — each got its round-robin budget and
            # finished
            assert max(conc) == 5, conc
        else:
            assert conc == [1, 1, 1, 1], conc
    assert waits["pooled"] < waits["serial"], waits
    # direct-generate results carry the same (idle) metric fields
    res = eng.generate(jnp.asarray(_toks(cfg, 5, seed=409))[None], 2)
    assert res.queue_wait_s == 0.0 and res.concurrent_admissions == 1


def test_idle_drain_tight_loop(setup):
    """With no decode slot active, pending admissions drain in a tight
    loop instead of one k-group unit per full scheduling pass — and the
    result stays token-identical."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256)
    prompt = _toks(cfg, 6 * seg, seed=500)
    sched = ContinuousScheduler(eng, n_slots=2, chunk=4,
                                prefill_groups_per_chunk=1)
    got = _collect(sched.run([Request("solo", prompt, 5)]))
    assert sched.idle_drain_rounds >= 4     # most rounds ran in the tight loop
    ref = eng.generate(jnp.asarray(prompt)[None], 5).tokens[0]
    assert got["solo"] == ref.tolist()


# ---------------------------------------------------------------------------
# Donation safety: pooled carries alias nothing across the launch
# ---------------------------------------------------------------------------

def test_pool_carries_never_alias(setup):
    """Regression for the pooled stepper's donation contract: member
    carries returned by a pooled launch are pairwise fresh (never each
    other's buffers, never the prefix cache's, never the inputs'), pads
    are fresh zeros — so simulating the donation a GPU/TPU backend would
    perform (deleting every input carry after the launch) leaves three
    concurrent admissions that still finish with the blocking prefill's
    logits, with the cache intact."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    cache = PrefixCache(seg, max_bytes=64 << 20)
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      prefix_cache=cache)
    warm = _toks(cfg, 3 * seg, seed=600)
    eng.generate(warm[None], 2)                      # fills the cache
    snap_ptrs = set()
    for slot in cache._lru.entries.values():
        snap_ptrs |= _leaf_ptrs(slot.payload)

    prompts = [np.concatenate([warm, _toks(cfg, 2 * seg + 4, seed=601 + i)])
               for i in range(3)]
    # reference on a cache-free engine: eng._prefill would insert each
    # prompt's own 5-segment prefix and turn the pipes into tail-only hits
    ref_eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256)
    refs = [ref_eng._prefill(jnp.asarray(p)[None]) for p in prompts]

    pool = AdmissionPool(eng)
    pipes = [eng.start_prefill(p[None], groups_per_call=1) for p in prompts]
    for pipe in pipes:
        assert pipe.cached == 3
        pool.add(pipe)
    assert pool.grid_cells_remaining() == 3 * 2 * eng._n_layers

    # first pooled round: 3 members -> pow2 pool of 4 (one pad exercised)
    buckets = pool.diag_buckets()
    assert list(buckets) == [(2, True, False, 1)]
    in_carries = [c for _, _, c in buckets[(2, True, False, 1)]]
    in_ptrs = set().union(*[_leaf_ptrs(c) for c in in_carries])
    done = pool.advance_round()
    assert done == []
    out_ptr_sets = [_leaf_ptrs(p._carry) for p in pipes]
    for i, ptrs in enumerate(out_ptr_sets):
        assert not (ptrs & snap_ptrs), "carry aliases the prefix cache"
        assert not (ptrs & in_ptrs), "carry aliases a donated input"
        for j in range(i + 1, 3):
            assert not (ptrs & out_ptr_sets[j]), "carries alias each other"

    # simulate donation: delete the inputs the pooled launch consumed,
    # then drive the pool to completion through further pooled rounds
    for c in in_carries:
        for leaf in jax.tree_util.tree_leaves(c):
            if isinstance(leaf, jax.Array):
                leaf.delete()
    while pool.members:
        pool.advance_round()
    assert pool.grid_cells_remaining() == 0
    for pipe, ref, p in zip(pipes, refs, prompts):
        logits, _dstate, pos, cached = pipe.result()
        np.testing.assert_allclose(np.asarray(logits), np.asarray(ref[0]),
                                   rtol=1e-3, atol=1e-5)
        assert pos == ref[2] and cached == 3
    # and the cache survived the donated carries: a fresh admission hits
    pipe2 = eng.start_prefill(jnp.asarray(prompts[0])[None])
    assert pipe2.cached >= 3


# ---------------------------------------------------------------------------
# 8-fake-device mesh parity (subprocess, slow-marked)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import dataclasses
import numpy as np
import jax
jax.config.update("jax_default_matmul_precision", "highest")
from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve import Request, ServeEngine
from repro.launch.mesh import parse_mesh

cfg = dataclasses.replace(get_smoke_config("h2o-danube-1.8b"), n_kv_heads=4)
params = init_params(cfg, jax.random.PRNGKey(0))
seg = cfg.armt.segment_len
rng = np.random.default_rng(7)

ref_eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256)
reqs = [Request(req_id=f"r{i}",
                prompt=rng.integers(8, cfg.vocab, (L,)).astype(np.int32),
                max_new=5)
        for i, L in enumerate([2 * seg, 2 * seg, seg + 3, 7])]
refs = {r.req_id: ref_eng.generate(np.asarray(r.prompt)[None], 5).tokens[0]
        for r in reqs}

for spec in ("data=2,model=4", "stage=2,model=4"):
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      mesh=parse_mesh(spec))
    for kw in (dict(max_concurrent_admissions=2),
               dict(max_concurrent_admissions=3),
               dict(fused_admission=True, max_concurrent_admissions=3)):
        outs = {}
        for ev in eng.serve(list(reqs), n_slots=3, chunk=3,
                            prefill_groups_per_chunk=2, **kw):
            outs.setdefault(ev.req_id, []).append(ev.token)
        for r in reqs:
            assert outs[r.req_id] == refs[r.req_id].tolist(), \
                (spec, kw, r.req_id)
    print(f"OK concurrent_{spec.split(',')[0].split('=')[0]}")
"""


@pytest.mark.slow
def test_concurrent_admissions_sharded_token_identical():
    """Pooled concurrent admissions (incl. the fused global-grid launch)
    on 8-fake-device TP and stage-pipeline meshes are token-identical to
    the single-device reference — the carry pool crosses GSPMD programs
    via pool_carry_specs. Subprocess because XLA_FLAGS must be set before
    jax imports (test_serve_sharded.py pattern); timeout skips."""
    try:
        r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                           capture_output=True, text=True, timeout=600,
                           env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                "HOME": "/root"})
    except subprocess.TimeoutExpired:
        pytest.skip("concurrent-mesh subprocess exceeded 600s: environment "
                    "too constrained to compile the 8-fake-device GSPMD "
                    "programs — exactness is asserted whenever the compile "
                    "finishes (CI runs this in the sharded-serving step)")
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-2000:])
    for m in ("concurrent_data", "concurrent_stage"):
        assert f"OK {m}" in r.stdout, (m, r.stdout[-1000:])
