"""Sharding rules: divisibility safety, ZeRO-1 moment sharding, batch axes,
and an end-to-end small-mesh lowering (8 fake devices, subprocess)."""
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.model import param_specs
from repro.parallel import sharding as shd


class FakeMesh:
    """Minimal stand-in exposing axis_names/shape for rule tests."""
    def __init__(self, shape):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


def test_param_leaf_rules():
    tp = 16
    # vocab divisible -> shard vocab
    assert shd.param_leaf_spec(["embed"], (32000, 2560), tp) == P("model", None)
    # whisper vocab NOT divisible -> shard d_model instead
    assert shd.param_leaf_spec(["embed"], (51865, 1024), tp) == P(None, "model")
    # attention column/row parallel
    assert shd.param_leaf_spec(["attn", "wq"], (2560, 2560), tp) == P(None, "model")
    assert shd.param_leaf_spec(["attn", "wo"], (2560, 2560), tp) == P("model", None)
    # MoE expert parallelism when E divides
    assert shd.param_leaf_spec(["moe", "wg"], (384, 7168, 2048), tp) == \
        P("model", None, None)
    # qwen2-moe: 60 experts don't divide 16 -> shard FFN dim
    assert shd.param_leaf_spec(["moe", "wg"], (60, 2048, 1408), tp) == \
        P(None, None, "model")
    # shared expert uses dense FFN rules, not expert rules
    assert shd.param_leaf_spec(["moe", "shared", "wd"], (5632, 2048), tp) == \
        P("model", None)
    # ARMT memory: wv value-dim sharded, wq/wk replicated
    assert shd.param_leaf_spec(["mem", "wv"], (2560, 2560), tp) == P(None, "model")
    assert shd.param_leaf_spec(["mem", "wq"], (2560, 64), tp) == P(None, None)


def test_every_arch_has_valid_specs():
    """All sharded dims must divide the axis size — for every arch."""
    from repro.configs import ASSIGNED_ARCHS
    mesh_shape = {"data": 16, "model": 16}
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        shapes = param_specs(cfg)
        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        for path, leaf in flat:
            names = shd._path_names(path)
            stacked = ("pattern" in names) or ("enc" in names and "blocks" in names)
            shape = leaf.shape[1:] if stacked else leaf.shape
            spec = shd.param_leaf_spec(names, shape, 16)
            for dim, ax in enumerate(spec):
                if ax is not None:
                    assert shape[dim] % 16 == 0, (arch, names, shape, spec)


def test_batch_axes():
    m = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert shd.batch_axes(m, 256) == ("pod", "data")
    assert shd.batch_axes(m, 2) == "pod"
    assert shd.batch_axes(m, 1) is None
    m2 = FakeMesh({"data": 16, "model": 16})
    assert shd.batch_axes(m2, 32) == "data"


SMALL_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.launch.specs import build_cell
from repro.configs import get_smoke_config

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_smoke_config("qwen2-moe-a2.7b")
import dataclasses
# make smoke dims divisible by model=4
cfg = dataclasses.replace(cfg, d_model=32, n_heads=4, n_kv_heads=4, d_head=8)
with mesh:
    cell = build_cell("qwen2-moe-a2.7b", "train_4k", mesh, cfg_override=cfg,
                      schedule="sequential")
    # shrink the batch spec to smoke scale
    import jax.numpy as jnp
    from jax import ShapeDtypeStruct as SDS
    batch = {"tokens": SDS((8, 64), jnp.int32), "labels": SDS((8, 64), jnp.int32)}
    from repro.parallel import sharding as shd
    lowered = jax.jit(cell.fn, in_shardings=(cell.in_shardings[0],
                                             shd.batch_specs(mesh, batch)),
                      out_shardings=cell.out_shardings).lower(cell.args[0], batch)
    compiled = lowered.compile()
    print("COMPILED_OK", compiled.cost_analysis().get("flops", 0) > 0)
"""


@pytest.mark.slow
def test_small_mesh_train_step_compiles():
    r = subprocess.run([sys.executable, "-c", SMALL_MESH_SCRIPT],
                       capture_output=True, text=True, timeout=420,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "COMPILED_OK True" in r.stdout, r.stderr[-2000:]
