"""Data pipeline: determinism, exact resume, needle-task structure."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test extra ([test] in pyproject)
from hypothesis import given, settings, strategies as st

from repro.data import lm_stream, needle_qa
from repro.data.synthetic import ANSWER, QUERY


def test_lm_stream_deterministic():
    a = next(lm_stream(256, 2, 32, seed=3))
    b = next(lm_stream(256, 2, 32, seed=3))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = next(lm_stream(256, 2, 32, seed=4))
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_lm_stream_resume():
    """start_step=k reproduces the k-th batch — exact data resume after a
    restart (fault tolerance)."""
    it = lm_stream(256, 2, 32, seed=0)
    batches = [next(it) for _ in range(4)]
    it2 = lm_stream(256, 2, 32, seed=0, start_step=3)
    np.testing.assert_array_equal(batches[3]["tokens"],
                                  next(it2)["tokens"])


@given(st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_needle_structure(step):
    it = needle_qa(512, 4, 64, seed=1, start_step=step)
    b = next(it)
    toks, labels, mask = b["tokens"], b["labels"], b["loss_mask"]
    assert toks.shape == (4, 64)
    # query comes right before the answer slot
    assert (toks[:, -3] == QUERY).all()
    assert (toks[:, -1] == ANSWER).all()
    # loss mask selects exactly the answer position
    assert mask.sum() == 4 and (mask[:, -1] == 1).all()
    # the gold label at the answer position is the planted value
    assert (labels[:, -1] == b["answer"]).all()
    # the value actually appears earlier in the sequence (the needle)
    for i in range(4):
        assert b["answer"][i] in toks[i, :-3]


def test_labels_are_shifted_tokens():
    b = next(lm_stream(128, 2, 16, seed=0))
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
