"""cfg.remat must actually reach the executors (DESIGN.md §15).

The executor-level ``jax.checkpoint`` wrap (run_diagonal / pipeline_step)
has always covered the vmap path; PR 10 threads ``remat`` into the fused
grouped cell (``make_grouped_apply``) and the serve prefill stepper so the
bounded-memory guarantee holds on every path. These are regression tests
that the flag survives the plumbing: they walk the traced jaxpr (including
pjit/scan/cond sub-jaxprs) for the checkpoint primitive instead of trusting
the keyword to be forwarded.

``jax.checkpoint`` only changes what the *backward* pass holds live;
forward values must be bitwise unchanged — asserted here too, because the
serving paths rely on remat being a free (exactness-neutral) default.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARMTConfig, get_smoke_config
from repro.core import diagonal as diag
from repro.core.schedule import StackLayout
from repro.models import init_params
from repro.models.grouped_blocks import make_grouped_apply
from repro.models.model import embed_segments, init_state

# the checkpoint primitive's registered name in current jax ("remat2"; the
# original "remat" in very old releases) — match by prefix so either works
_REMAT_PREFIX = "remat"


def _subjaxprs(v):
    if hasattr(v, "eqns"):          # raw Jaxpr
        return [v]
    if hasattr(v, "jaxpr"):         # ClosedJaxpr
        return [v.jaxpr]
    if isinstance(v, (list, tuple)):
        out = []
        for item in v:
            out.extend(_subjaxprs(item))
        return out
    return []


def count_remat(jaxpr) -> int:
    """Occurrences of the checkpoint primitive anywhere in ``jaxpr``,
    recursing through every sub-jaxpr carried in equation params (pjit
    bodies, scan/while bodies, cond branches, custom_vjp calls)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name.startswith(_REMAT_PREFIX):
            n += 1
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                n += count_remat(sub)
    return n


def _cfg(**kw):
    base = dataclasses.replace(
        get_smoke_config("llama-1b-armt"), n_layers=4, d_model=32, n_heads=2,
        n_kv_heads=2, d_head=16, d_ff=64, max_position=4096, dtype="float32",
        armt=ARMTConfig(segment_len=16, num_mem_tokens=4, d_mem=8))
    return dataclasses.replace(base, **kw) if kw else base


def _stacked_inputs(cfg, B=2):
    params = init_params(cfg, jax.random.PRNGKey(0))
    p0 = params["pattern"][0]
    G = cfg.n_layers
    T = cfg.armt.segment_len + cfg.armt.num_mem_tokens
    x = jax.random.normal(jax.random.PRNGKey(1), (G, B, T, cfg.d_model),
                          jnp.float32)
    st = init_state(cfg, B, "segmented", jnp.float32)["pattern"][0]
    return params, p0, x, st


def test_fused_grouped_cell_remat():
    """make_grouped_apply(remat=True) wraps the fused attn cell in
    jax.checkpoint; remat=False compiles checkpoint-free; forward values
    are bitwise identical either way."""
    cfg = _cfg()
    _, p0, x, st = _stacked_inputs(cfg)
    outs = {}
    for remat in (False, True):
        ga = make_grouped_apply(cfg, mode="segmented", ssm_method="assoc",
                                remat=remat)
        jaxpr = jax.make_jaxpr(lambda p, h, s: ga("attn", p, h, s))(p0, x, st)
        n = count_remat(jaxpr.jaxpr)
        assert (n > 0) == remat, (remat, n)
        outs[remat] = ga("attn", p0, x, st)
    y0, st0 = outs[False]
    y1, st1 = outs[True]
    assert (y0 == y1).all()
    for a, b in zip(jax.tree_util.tree_leaves(st0),
                    jax.tree_util.tree_leaves(st1)):
        assert (a == b).all()


def test_blockwise_cell_remats_per_block():
    """cell_block > 0 adds the per-chunk checkpoint inside the blockwise
    FFN even when the outer cell-level remat is off."""
    cfg = _cfg(cell_block=8)
    _, p0, x, st = _stacked_inputs(cfg)
    ga = make_grouped_apply(cfg, mode="segmented", ssm_method="assoc",
                            remat=False)
    jaxpr = jax.make_jaxpr(lambda p, h, s: ga("attn", p, h, s))(p0, x, st)
    assert count_remat(jaxpr.jaxpr) > 0


@pytest.mark.parametrize("grouped_impl", ["vmap", "fused"])
def test_prefill_stepper_remat(grouped_impl):
    """The serve prefill stepper (ServeEngine.prefill_step ->
    diag.pipeline_step) recompiles with checkpoint active iff cfg.remat is
    on; the fused engine additionally carries the cell-level checkpoint."""
    from repro.serve.engine import ServeEngine

    counts = {}
    for remat_mode in ("none", "full"):
        cfg = _cfg(remat=remat_mode, grouped_impl=grouped_impl)
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(params, cfg)
        step = eng.prefill_step(4, 1, False, 2)
        stats = eng.prefill_memory_stats(4, stream=True, n_groups=2)
        assert stats["temp_bytes"] is not None
        xs_abs, carry_abs = jax.eval_shape(
            lambda x: diag.pipeline_init(
                StackLayout.from_config(cfg),
                init_state(cfg, 1, "segmented", jnp.float32), x),
            jax.ShapeDtypeStruct((4, 1, eng.seg_len
                                  + cfg.armt.num_mem_tokens, cfg.d_model),
                                 jnp.float32))
        jaxpr = jax.make_jaxpr(step)(params, xs_abs, carry_abs)
        counts[remat_mode] = count_remat(jaxpr.jaxpr)
    assert counts["none"] == 0, counts
    assert counts["full"] > 0, counts


def test_run_diagonal_remat_forward_neutral():
    """Executor-level remat on run_diagonal: checkpoint shows up in the
    trace and the forward outputs (ys + final state) stay bitwise equal."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    layout = StackLayout.from_config(cfg)
    B, S = 1, 3
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S * 16), 0,
                                cfg.vocab)
    segs = embed_segments(params, cfg, tokens, 16, True)
    state0 = init_state(cfg, B, "segmented", segs.dtype)
    exec_params = {"prelude": params.get("prelude", ()),
                   "pattern": params["pattern"]}
    from repro.models.blocks import make_apply_block
    apply = make_apply_block(cfg, mode="segmented", ssm_method="assoc")

    def run(remat):
        return diag.run_diagonal(layout, exec_params, state0, segs, apply,
                                 remat=remat)
    jaxpr = jax.make_jaxpr(lambda: run(True))()
    assert count_remat(jaxpr.jaxpr) > 0
    ys0, st0 = run(False)
    ys1, st1 = run(True)
    assert (ys0 == ys1).all()
    for a, b in zip(jax.tree_util.tree_leaves(st0),
                    jax.tree_util.tree_leaves(st1)):
        assert (a == b).all()
