"""Mamba: scan vs chunked-associative equivalence, segment-carry exactness,
single-token decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SSMConfig
from repro.models.mamba import (mamba_mixer, mamba_param_init,
                                mamba_state_init, selective_scan)


def _inputs(key, B, T, dI, dS):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, T, dI)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, dI)))
    Bt = jax.random.normal(ks[2], (B, T, dS)) * 0.5
    Ct = jax.random.normal(ks[3], (B, T, dS)) * 0.5
    A_log = jnp.log(jnp.tile(jnp.arange(1., dS + 1)[None], (dI, 1)))
    h0 = jnp.zeros((B, dI, dS))
    return x, dt, Bt, Ct, A_log, h0


@pytest.mark.parametrize("T,chunk", [(16, 4), (17, 8), (32, 32), (8, 16)])
def test_scan_equals_assoc(T, chunk):
    x, dt, Bt, Ct, A_log, h0 = _inputs(jax.random.PRNGKey(T), 2, T, 12, 4)
    y1, h1 = selective_scan(x, dt, Bt, Ct, A_log, h0, method="scan")
    y2, h2 = selective_scan(x, dt, Bt, Ct, A_log, h0, method="assoc",
                            chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=1e-4, rtol=1e-4)


def test_segment_carry_exact():
    """Processing [T] in one call == two calls of [T/2] with carried state
    (the PRMT layer-local recurrence the diagonal executor relies on)."""
    scfg = SSMConfig(d_state=4, d_conv=4, expand=2)
    D = 8
    p = mamba_param_init(jax.random.PRNGKey(0), D, scfg, jnp.float32)
    st0 = mamba_state_init(2, D, scfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, D))
    y_full, _ = mamba_mixer(x, p, scfg, st0)
    y1, st1 = mamba_mixer(x[:, :8], p, scfg, st0)
    y2, st2 = mamba_mixer(x[:, 8:], p, scfg, st1)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate([y1, y2], 1)),
                               atol=1e-5, rtol=1e-5)


def test_token_decode_matches_segment():
    scfg = SSMConfig(d_state=4, d_conv=4, expand=2)
    D = 8
    p = mamba_param_init(jax.random.PRNGKey(0), D, scfg, jnp.float32)
    st = mamba_state_init(1, D, scfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, D))
    y_seg, _ = mamba_mixer(x, p, scfg, st)
    ys = []
    for t in range(6):
        y_t, st = mamba_mixer(x[:, t:t + 1], p, scfg, st)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(y_seg),
                               np.asarray(jnp.concatenate(ys, 1)),
                               atol=1e-5, rtol=1e-5)
