"""Dispatch + autotune subsystem (kernels/dispatch.py, kernels/autotune.py;
DESIGN.md §14): resolution order, shape bucketing, the sweep/validate/cache
loop, and the acceptance invariants — a warm cache performs ZERO sweep
launches, and every config the dispatcher can hand out bit-validates in
interpret mode against the ref.py oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch, ops, ref
from repro.kernels.autotune import _REFS, Autotuner, config_space, run_op
from repro.kernels.dispatch import KernelConfig
from repro.serve.telemetry import MetricsRegistry, default_registry


@pytest.fixture
def cache(tmp_path):
    """Point the dispatch cache at a throwaway file; restore after."""
    path = str(tmp_path / "kernel_cache.json")
    dispatch.set_cache_path(path)
    yield path
    dispatch.set_cache_path(None)


def _tiny_args(op):
    """Small, fast operand sets per op (interpret-mode friendly)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 10)
    if op == "grouped_matmul":
        return (jax.random.normal(ks[0], (2, 16, 12)),
                jax.random.normal(ks[1], (2, 12, 20))), {}
    if op == "grouped_matmul_armt_update":
        G, R, K, D, dm, M = 2, 12, 8, 16, 4, 2
        P = 6 * dm
        return (jax.random.normal(ks[0], (G, R, K)) * 0.3,
                jax.random.normal(ks[1], (G, K, D)) * 0.3,
                jax.random.normal(ks[2], (G, R, D)) * 0.3,
                jax.random.normal(ks[3], (G, D, dm)) * 0.3,
                jax.random.normal(ks[4], (G, D, D)) * 0.3,
                jax.random.normal(ks[5], (G, D, 1)) * 0.3,
                jax.random.normal(ks[6], (G, P, D)) * 0.1,
                jax.random.normal(ks[7], (G, P)) * 0.1), {"M": M}
    if op == "flash_attention":
        q = jax.random.normal(ks[0], (2, 2, 16, 8))
        k = jax.random.normal(ks[1], (2, 2, 16, 8))
        v = jax.random.normal(ks[2], (2, 2, 16, 8))
        return (q, k, v), {}
    if op == "decode_attention":
        return (jax.random.normal(ks[0], (2, 2, 8)),
                jax.random.normal(ks[1], (2, 16, 2, 8)),
                jax.random.normal(ks[2], (2, 16, 2, 8)),
                jnp.array([3, 16], jnp.int32)), {}
    if op == "armt_read":
        dm = 4
        return (jax.random.normal(ks[0], (2, 8, 12)),
                jax.random.normal(ks[1], (12, dm)) * 0.3,
                jax.random.normal(ks[2], (2, 6 * dm, 16)) * 0.1,
                jax.random.uniform(ks[3], (2, 6 * dm))), {}
    if op == "armt_update":
        dm = 4
        return (jax.random.normal(ks[0], (2, 2, 12)),
                jax.random.normal(ks[1], (12, dm)) * 0.3,
                jax.random.normal(ks[2], (12, 16)) * 0.3,
                jax.random.normal(ks[3], (12, 1)) * 0.3,
                jax.random.normal(ks[4], (2, 6 * dm, 16)) * 0.1,
                jax.random.uniform(ks[5], (2, 6 * dm))), {}
    if op == "mamba_scan":
        return (jax.random.normal(ks[0], (1, 8, 8)) * 0.5,
                jax.nn.softplus(jax.random.normal(ks[1], (1, 8, 8))),
                jax.random.normal(ks[2], (1, 8, 4)) * 0.5,
                jax.random.normal(ks[3], (1, 8, 4)) * 0.5,
                jnp.log(jnp.tile(jnp.arange(1., 5.)[None], (8, 1))),
                jnp.ones(8),
                jax.random.normal(ks[4], (1, 8, 4)) * 0.1), {}
    raise ValueError(op)


# ---------------------------------------------------------------- resolution

def test_cpu_default_dispatches_to_xla(cache):
    cfg = dispatch.resolve("grouped_matmul", ((2, 16, 12), (2, 12, 20)),
                           jnp.float32)
    assert cfg.impl == "xla"


def test_per_call_override_beats_everything(cache):
    cfg = dispatch.resolve("grouped_matmul", ((2, 16, 12), (2, 12, 20)),
                           jnp.float32, use_kernel=True, interpret=True)
    assert cfg.impl == "pallas" and cfg.interpret
    cfg = dispatch.resolve("flash_attention", ((2, 2, 16, 8),) * 2,
                           jnp.float32, use_kernel=False)
    assert cfg.impl == "xla"


def test_kernel_backend_knob(cache):
    shapes = ((2, 16, 12), (2, 12, 20))
    cfg = dispatch.resolve("grouped_matmul", shapes, jnp.float32,
                           kernel_backend="pallas_interpret")
    assert cfg.impl == "pallas" and cfg.interpret
    cfg = dispatch.resolve("grouped_matmul", shapes, jnp.float32,
                           kernel_backend="xla")
    assert cfg.impl == "xla"
    # explicit per-call override still wins over the knob
    cfg = dispatch.resolve("grouped_matmul", shapes, jnp.float32,
                           kernel_backend="pallas", use_kernel=False)
    assert cfg.impl == "xla"


def test_shape_bucketing_pow2():
    k1 = dispatch.cache_key("cpu", "grouped_matmul",
                            ((2, 60, 33), (2, 33, 100)), jnp.float32)
    k2 = dispatch.cache_key("cpu", "grouped_matmul",
                            ((2, 64, 64), (2, 64, 128)), jnp.float32)
    assert k1 == k2                      # same pow2 bucket
    k3 = dispatch.cache_key("cpu", "grouped_matmul",
                            ((2, 65, 64), (2, 64, 128)), jnp.float32)
    assert k3 != k2                      # crossed a pow2 boundary
    assert dispatch.cache_key("tpu", "grouped_matmul",
                              ((2, 64, 64), (2, 64, 128)),
                              jnp.bfloat16) != k2   # backend+dtype keyed


def test_dispatch_counters_in_registry(cache):
    reg = default_registry()
    reg.remove_series("kernel_dispatch_total")
    dispatch.resolve("armt_read", ((2, 8, 12), (2, 24, 16)), jnp.float32)
    key = ("kernel_dispatch_total{backend=cpu,impl=xla,op=armt_read,"
           "source=heuristic}")
    assert reg.counters.get(key) == 1


def test_heuristic_table_covers_every_op_and_backend():
    for bk in dispatch.BACKENDS:
        for op in dispatch.OPS:
            cfg = dispatch.heuristic(op, bk)
            assert cfg.impl in ("xla", "pallas")
            if bk == "cpu":
                assert cfg.impl == "xla"
            if bk == "interpret":
                assert cfg.interpret


# ---------------------------------------------------------------- autotuner

def test_cold_sweep_then_warm_cache_hits_zero_sweeps(cache):
    """The acceptance invariant: first run sweeps + validates + persists;
    a second run (fresh tuner, fresh registry, reloaded disk cache)
    performs ZERO sweep launches and serves the same winner."""
    args, kw = _tiny_args("grouped_matmul")
    reg1 = MetricsRegistry()
    tuner1 = Autotuner(cache, registry=reg1)
    winner = tuner1.get_or_tune("grouped_matmul", args, backend="interpret",
                                repeats=1, op_kwargs=kw)
    sweeps = sum(v for k, v in reg1.counters.items()
                 if k.startswith("autotune_sweep_total"))
    assert sweeps > 0
    assert reg1.counters.get(
        "autotune_validate_total{op=grouped_matmul,result=pass}", 0) >= 1

    dispatch.set_cache_path(cache)       # drop in-memory table -> disk read
    reg2 = MetricsRegistry()
    tuner2 = Autotuner(cache, registry=reg2)
    again = tuner2.get_or_tune("grouped_matmul", args, backend="interpret",
                               repeats=1, op_kwargs=kw)
    assert again == winner
    assert sum(v for k, v in reg2.counters.items()
               if k.startswith("autotune_sweep_total")) == 0
    assert reg2.counters.get(
        "autotune_cache_hit_total{op=grouped_matmul}") == 1


def test_dispatch_serves_tuned_winner(cache):
    """After tuning, plain dispatch.resolve (the trace-time path) returns
    the cached winner for any shape in the same bucket."""
    args, kw = _tiny_args("armt_update")
    reg = MetricsRegistry()
    tuner = Autotuner(cache, registry=reg)
    winner = tuner.get_or_tune("armt_update", args, backend="cpu",
                               repeats=1, op_kwargs=kw)
    shapes = (args[0].shape, args[4].shape)
    got = dispatch.resolve("armt_update", shapes, args[0].dtype)
    assert got == winner


def test_validation_rejects_wrong_results(cache, monkeypatch):
    """A candidate whose output disagrees with the oracle must not win."""
    args, kw = _tiny_args("grouped_matmul")
    reg = MetricsRegistry()
    tuner = Autotuner(cache, registry=reg)
    monkeypatch.setitem(
        _REFS, "grouped_matmul",
        lambda x, w, b=None, **_: jnp.einsum("gmk,gkn->gmn", x, w) * 1.5)
    assert not tuner.validate("grouped_matmul", args,
                              KernelConfig(impl="xla"), op_kwargs=kw)
    assert reg.counters.get(
        "autotune_validate_total{op=grouped_matmul,result=fail}") == 1


def test_sweep_drops_unlowerable_candidates(cache):
    """Candidates that violate an op's shape constraints (e.g. the fused
    ARMT epilogue with mem rows straddling the last m-tile) are dropped,
    not fatal."""
    args, kw = _tiny_args("grouped_matmul_armt_update")
    reg = MetricsRegistry()
    tuner = Autotuner(cache, registry=reg)
    ranked = tuner.sweep("grouped_matmul_armt_update", args,
                         backend="interpret", repeats=1, op_kwargs=kw)
    assert ranked                         # something survived
    for cfg, t in ranked:
        assert t >= 0.0


# ------------------------------------------------- config bit-validation

@pytest.mark.parametrize("op", dispatch.OPS)
@pytest.mark.parametrize("bk", ["tpu", "gpu"])
def test_every_heuristic_config_bit_validates(op, bk):
    """Every config the heuristic table can dispatch runs the actual
    kernel body (interpret lowering) and matches the jnp oracle — the
    'every dispatched kernel config is bit-validated' acceptance gate."""
    cfg = dataclasses.replace(dispatch.heuristic(op, bk), impl="pallas",
                              interpret=True)
    args, kw = _tiny_args(op)
    got = run_op(op, args, cfg, **kw)
    want = _REFS[op](*args, **kw)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=2e-5, rtol=2e-4), got, want)


def test_config_space_sane():
    for op in dispatch.OPS:
        cpu = config_space(op, "cpu")
        # CPU never sweeps pallas (interpret is a validation lowering,
        # not an execution engine); flash_attention additionally sweeps
        # the XLA-lowering variants (fast_softmax / causal_blocks)
        assert cpu[0] == dispatch.XLA
        assert all(c.impl == "xla" for c in cpu)
        if op == "flash_attention":
            assert any(c.fast_softmax for c in cpu)
            assert any(c.causal_blocks for c in cpu)
        else:
            assert cpu == [dispatch.XLA]
        interp = config_space(op, "interpret")
        assert interp and all(c.interpret for c in interp)
        tpu = config_space(op, "tpu")
        assert dispatch.XLA in tpu       # XLA-native always competes
        assert any(c.impl == "pallas" for c in tpu)


def test_cpu_attention_variants_validate_against_oracle():
    """Every CPU flash_attention candidate (and the heuristic winner) is
    numerically validated against the grouped oracle on the 5-D layout —
    the same gate autotuned winners pass before entering the cache."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 1, 16, 2, 8))
    k = jax.random.normal(ks[1], (2, 1, 16, 2, 8))
    v = jax.random.normal(ks[2], (2, 1, 16, 2, 8))
    reg = MetricsRegistry()
    tuner = Autotuner(persist=False, registry=reg)
    for cfg in config_space("flash_attention", "cpu"):
        assert tuner.validate("flash_attention", (q, k, v), cfg)
    assert tuner.validate("flash_attention", (q, k, v),
                          dispatch.heuristic("flash_attention", "cpu"))
    # the exact oracle config stays bitwise-equal to the grouped ref
    got = run_op("flash_attention", (q, k, v), dispatch.XLA)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(_REFS["flash_attention"](q, k, v)))
