"""The fused grouped-block path (models/grouped_blocks.py) must match the
vmap path — and therefore the sequential executor — to fp32 tolerance, with
the real Pallas kernel bodies exercised on CPU via interpret=True (the
acceptance invariant of the grouped execution fast mode)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import StackLayout, run_diagonal, run_sequential
from repro.models import forward_hidden, init_params
from repro.models.blocks import make_apply_block
from repro.models.grouped_blocks import make_grouped_apply
from repro.models.model import embed_segments, init_state

ATOL, RTOL = 2e-4, 2e-3    # fp32; flash online-softmax vs dense sdpa


def _allclose(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32),
            atol=ATOL, rtol=RTOL),
        a, b)


def _setup(arch, S=4, B=2, key=0, **over):
    cfg = dataclasses.replace(get_smoke_config(arch), **over)
    params = init_params(cfg, jax.random.PRNGKey(key))
    seg = cfg.armt.segment_len
    toks = jax.random.randint(jax.random.PRNGKey(key + 1), (B, S * seg),
                              0, cfg.vocab)
    return cfg, params, toks


def _run(cfg, params, toks, *, schedule, grouped_apply=None, band_skip=None):
    layout = StackLayout.from_config(cfg)
    with_mem = cfg.armt is not None and cfg.armt.num_mem_tokens > 0
    x = embed_segments(params, cfg, toks, cfg.armt.segment_len, with_mem)
    state0 = init_state(cfg, toks.shape[0], "segmented",
                        params["embed"].dtype)
    apply = make_apply_block(cfg, mode="segmented")
    ep = {"prelude": params["prelude"], "pattern": params["pattern"]}
    if schedule == "diagonal":
        return run_diagonal(layout, ep, state0, x, apply,
                            grouped_apply=grouped_apply,
                            band_skip=band_skip)
    return run_sequential(layout, ep, state0, x, apply)


@pytest.mark.parametrize("over", [
    {},                                          # llama: rmsnorm+swiglu GQA
    {"sliding_window": 8},                       # windowed flash path
    {"norm": "layernorm", "act": "gelu"},        # bias epilogue (qkv + mlp)
    {"qk_norm": True},
], ids=["base", "window", "layernorm_gelu_bias", "qk_norm"])
def test_fused_matches_vmap_and_sequential(over):
    """attn pattern + ARMT memory: fused (interpret=True kernels) == vmap ==
    sequential — the paper's 'pure reordering' plus our 'pure re-lowering'.

    S=3 here: the delta-rule recurrence amplifies the kernels' ~1e-6
    online-softmax rounding through the read denominator (pq.z + eps), the
    paper's Table-2 error-accumulation effect — long-horizon *structural*
    equivalence is covered exactly by test_fused_structure_is_exact."""
    cfg, params, toks = _setup("llama-1b-armt", S=3, **over)
    fused = make_grouped_apply(cfg, use_kernel=True, interpret=True)
    ys_f, st_f = _run(cfg, params, toks, schedule="diagonal",
                      grouped_apply=fused)
    ys_v, st_v = _run(cfg, params, toks, schedule="diagonal")
    ys_s, st_s = _run(cfg, params, toks, schedule="sequential")
    _allclose(ys_f, ys_v)
    _allclose(st_f, st_v)
    _allclose(ys_f, ys_s)
    _allclose(st_f, st_s)
    # ARMT memory state actually evolved (the fused path ran the update)
    assert float(jnp.abs(st_f["pattern"][0]["A"]).max()) > 0


@pytest.mark.parametrize("over", [{}, {"norm": "layernorm", "act": "gelu"}],
                         ids=["swiglu", "gelu_bias"])
def test_fused_armt_epilogue_matches_vmap(over):
    """B=1 (the serving/admission layout) routes the down-proj + memory
    update through the single grouped_gemm_armt_update launch
    (grouped_blocks.fused_attn's fuse_update path) — must still match the
    vmap oracle. B=2 above covers the two-launch fallback, so together the
    pair pins both sides of the fusability branch."""
    cfg, params, toks = _setup("llama-1b-armt", S=3, B=1, **over)
    fused = make_grouped_apply(cfg, use_kernel=True, interpret=True)
    ys_f, st_f = _run(cfg, params, toks, schedule="diagonal",
                      grouped_apply=fused)
    ys_v, st_v = _run(cfg, params, toks, schedule="diagonal")
    _allclose(ys_f, ys_v)
    _allclose(st_f, st_v)
    assert float(jnp.abs(st_f["pattern"][0]["A"]).max()) > 0


def test_fused_structure_is_exact():
    """With the jnp oracles (use_kernel=False) the fused path is the *same
    math* as the vmap path — grouped einsums, broadcast norms, and flattened
    memory reads must agree to fp32 ulp over a longer recurrence (S=5)."""
    cfg, params, toks = _setup("llama-1b-armt", S=5)
    fused = make_grouped_apply(cfg, use_kernel=False)
    # band_skip=False isolates the grouped-apply *math* from the banded
    # driver: same full-width step body as vmap, so agreement must be ulp
    # (the banded driver's separate equivalence is test_banded_* below and
    # tests/test_executors.py — XLA picks different reduction strategies
    # per group size, so ulp-exactness cannot survive band slicing)
    ys_f, st_f = _run(cfg, params, toks, schedule="diagonal",
                      grouped_apply=fused, band_skip=False)
    ys_v, st_v = _run(cfg, params, toks, schedule="diagonal")
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32), atol=1e-6),
        (ys_f, st_f), (ys_v, st_v))


@pytest.mark.parametrize("S", [1, 2, 3])
def test_banded_driver_matches_full(S):
    """The banded fused driver (band_skip=True, the default for the fused
    path) == the full-width body on the real model. Short recurrences only:
    band slicing changes group sizes, XLA picks different reduction
    strategies per group size (~1e-6 seeds), and the delta-rule recurrence
    amplifies those through the read denominator over longer horizons
    (the paper's Table-2 effect) — the *structural* bitwise equivalence of
    the banded schedule over long horizons is
    test_banded_driver_is_pure_reordering below."""
    cfg, params, toks = _setup("llama-1b-armt", S=S, B=1)
    fused = make_grouped_apply(cfg, use_kernel=False)
    ys_b, st_b = _run(cfg, params, toks, schedule="diagonal",
                      grouped_apply=fused, band_skip=True)
    ys_f, st_f = _run(cfg, params, toks, schedule="diagonal",
                      grouped_apply=fused, band_skip=False)
    _allclose(ys_b, ys_f)
    _allclose(st_b, st_f)


@pytest.mark.parametrize("S", [1, 2, 3, 5, 8, 11])
@pytest.mark.parametrize("L", [2, 3, 4, 8])
def test_banded_driver_is_pure_reordering(S, L):
    """Banded vs full-width with a toy block whose arithmetic is *exact* in
    f32 (elementwise ops on small dyadic rationals — no reductions, so no
    group-size-dependent rounding): the two drivers must agree bitwise at
    every (S, L) phase structure (fill/mid/drain, pow2 band buckets,
    S < L, S == L, S > L)."""
    layout = StackLayout(prelude=(), pattern=("blk",), n_super=L)
    x = jnp.round(jax.random.uniform(jax.random.PRNGKey(0),
                                     (S, 2, 3, 4)) * 4) / 4
    w = jnp.round(jax.random.uniform(jax.random.PRNGKey(1),
                                     (L, 1, 1, 1)) * 4) / 4
    params = {"prelude": (), "pattern": ({"w": w},)}
    state0 = {"prelude": (), "pattern": ({"acc": jnp.zeros((L, 2, 3, 4))},)}

    def apply_block(t, p, xx, s):
        y = xx * p["w"] + s["acc"]
        return y, {"acc": s["acc"] + y * 0.5}

    def grouped(t, pb, xb, sb):
        return jax.vmap(lambda pp, x1, s1: apply_block(t, pp, x1, s1))(
            pb, xb, sb)

    outs = {}
    for skip in (False, True):
        outs[skip] = run_diagonal(layout, params, state0, x, apply_block,
                                  grouped_apply=grouped, band_skip=skip)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)),
        outs[True], outs[False])


def test_banded_capture_states_matches_full():
    """capture_states through the banded driver re-assembles the same
    per-boundary snapshots as the full-width scan (the serving state-store
    capture path, serve/state_store.py)."""
    from repro.core.diagonal import boundary_states_from_capture
    cfg, params, toks = _setup("llama-1b-armt", S=3, B=1)
    layout = StackLayout.from_config(cfg)
    x = embed_segments(params, cfg, toks, cfg.armt.segment_len, True)
    state0 = init_state(cfg, 1, "segmented", params["embed"].dtype)
    apply = make_apply_block(cfg, mode="segmented")
    ep = {"prelude": params["prelude"], "pattern": params["pattern"]}
    fused = make_grouped_apply(cfg, use_kernel=False)
    outs = {}
    for skip in (False, True):
        ys, fin, cap = run_diagonal(layout, ep, state0, x, apply,
                                    grouped_apply=fused, band_skip=skip,
                                    capture_states=True)
        outs[skip] = (ys, fin, boundary_states_from_capture(layout, cap, 3))
    _allclose(outs[True], outs[False])


def test_fused_fallback_heterogeneous_pattern():
    """Patterns with non-attn blocks (jamba: attn + mamba + moe) fall back to
    the vmap path per position — the fused closure must stay equivalent."""
    cfg, params, toks = _setup("jamba-1.5-large-398b", S=3, B=1)
    fused = make_grouped_apply(cfg, use_kernel=True, interpret=True)
    ys_f, st_f = _run(cfg, params, toks, schedule="diagonal",
                      grouped_apply=fused)
    ys_v, st_v = _run(cfg, params, toks, schedule="diagonal")
    _allclose(ys_f, ys_v)
    _allclose(st_f, st_v)


def test_forward_hidden_grouped_impl_knob():
    """cfg/arg-level wiring: forward_hidden(grouped_impl='fused') matches the
    vmap default (auto kernel selection -> jnp oracles on CPU)."""
    cfg, params, toks = _setup("llama-1b-armt", S=3)
    h_v, fin_v = forward_hidden(params, cfg, toks, schedule="diagonal")
    h_f, fin_f = forward_hidden(params, cfg, toks, schedule="diagonal",
                                grouped_impl="fused")
    _allclose(h_f, h_v)
    _allclose(fin_f, fin_v)
    # cfg-level knob routes identically to the argument override
    cfg2 = dataclasses.replace(cfg, grouped_impl="fused")
    h_c, _ = forward_hidden(params, cfg2, toks, schedule="diagonal")
    _allclose(h_c, h_f)


def test_serve_engine_fused_prefill():
    """ServeEngine(grouped_impl='fused') produces the same prefill logits and
    decode state as the vmap engine."""
    from repro.serve import ServeEngine
    cfg, params, toks = _setup("llama-1b-armt", S=3, B=1)
    eng_v = ServeEngine(params, cfg, serve_mode="armt", schedule="diagonal",
                        max_len=256)
    eng_f = ServeEngine(params, cfg, serve_mode="armt", schedule="diagonal",
                        max_len=256, grouped_impl="fused")
    lg_v, st_v = eng_v.prefill(toks)
    lg_f, st_f = eng_f.prefill(toks)
    _allclose(lg_f, lg_v)
    _allclose(st_f, st_v)
