"""The fused grouped-block path (models/grouped_blocks.py) must match the
vmap path — and therefore the sequential executor — to fp32 tolerance, with
the real Pallas kernel bodies exercised on CPU via interpret=True (the
acceptance invariant of the grouped execution fast mode)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import StackLayout, run_diagonal, run_sequential
from repro.models import forward_hidden, init_params
from repro.models.blocks import make_apply_block
from repro.models.grouped_blocks import make_grouped_apply
from repro.models.model import embed_segments, init_state

ATOL, RTOL = 2e-4, 2e-3    # fp32; flash online-softmax vs dense sdpa


def _allclose(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32),
            atol=ATOL, rtol=RTOL),
        a, b)


def _setup(arch, S=4, B=2, key=0, **over):
    cfg = dataclasses.replace(get_smoke_config(arch), **over)
    params = init_params(cfg, jax.random.PRNGKey(key))
    seg = cfg.armt.segment_len
    toks = jax.random.randint(jax.random.PRNGKey(key + 1), (B, S * seg),
                              0, cfg.vocab)
    return cfg, params, toks


def _run(cfg, params, toks, *, schedule, grouped_apply=None):
    layout = StackLayout.from_config(cfg)
    with_mem = cfg.armt is not None and cfg.armt.num_mem_tokens > 0
    x = embed_segments(params, cfg, toks, cfg.armt.segment_len, with_mem)
    state0 = init_state(cfg, toks.shape[0], "segmented",
                        params["embed"].dtype)
    apply = make_apply_block(cfg, mode="segmented")
    ep = {"prelude": params["prelude"], "pattern": params["pattern"]}
    if schedule == "diagonal":
        return run_diagonal(layout, ep, state0, x, apply,
                            grouped_apply=grouped_apply)
    return run_sequential(layout, ep, state0, x, apply)


@pytest.mark.parametrize("over", [
    {},                                          # llama: rmsnorm+swiglu GQA
    {"sliding_window": 8},                       # windowed flash path
    {"norm": "layernorm", "act": "gelu"},        # bias epilogue (qkv + mlp)
    {"qk_norm": True},
], ids=["base", "window", "layernorm_gelu_bias", "qk_norm"])
def test_fused_matches_vmap_and_sequential(over):
    """attn pattern + ARMT memory: fused (interpret=True kernels) == vmap ==
    sequential — the paper's 'pure reordering' plus our 'pure re-lowering'.

    S=3 here: the delta-rule recurrence amplifies the kernels' ~1e-6
    online-softmax rounding through the read denominator (pq.z + eps), the
    paper's Table-2 error-accumulation effect — long-horizon *structural*
    equivalence is covered exactly by test_fused_structure_is_exact."""
    cfg, params, toks = _setup("llama-1b-armt", S=3, **over)
    fused = make_grouped_apply(cfg, use_kernel=True, interpret=True)
    ys_f, st_f = _run(cfg, params, toks, schedule="diagonal",
                      grouped_apply=fused)
    ys_v, st_v = _run(cfg, params, toks, schedule="diagonal")
    ys_s, st_s = _run(cfg, params, toks, schedule="sequential")
    _allclose(ys_f, ys_v)
    _allclose(st_f, st_v)
    _allclose(ys_f, ys_s)
    _allclose(st_f, st_s)
    # ARMT memory state actually evolved (the fused path ran the update)
    assert float(jnp.abs(st_f["pattern"][0]["A"]).max()) > 0


def test_fused_structure_is_exact():
    """With the jnp oracles (use_kernel=False) the fused path is the *same
    math* as the vmap path — grouped einsums, broadcast norms, and flattened
    memory reads must agree to fp32 ulp over a longer recurrence (S=5)."""
    cfg, params, toks = _setup("llama-1b-armt", S=5)
    fused = make_grouped_apply(cfg, use_kernel=False)
    ys_f, st_f = _run(cfg, params, toks, schedule="diagonal",
                      grouped_apply=fused)
    ys_v, st_v = _run(cfg, params, toks, schedule="diagonal")
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32), atol=1e-6),
        (ys_f, st_f), (ys_v, st_v))


def test_fused_fallback_heterogeneous_pattern():
    """Patterns with non-attn blocks (jamba: attn + mamba + moe) fall back to
    the vmap path per position — the fused closure must stay equivalent."""
    cfg, params, toks = _setup("jamba-1.5-large-398b", S=3, B=1)
    fused = make_grouped_apply(cfg, use_kernel=True, interpret=True)
    ys_f, st_f = _run(cfg, params, toks, schedule="diagonal",
                      grouped_apply=fused)
    ys_v, st_v = _run(cfg, params, toks, schedule="diagonal")
    _allclose(ys_f, ys_v)
    _allclose(st_f, st_v)


def test_forward_hidden_grouped_impl_knob():
    """cfg/arg-level wiring: forward_hidden(grouped_impl='fused') matches the
    vmap default (auto kernel selection -> jnp oracles on CPU)."""
    cfg, params, toks = _setup("llama-1b-armt", S=3)
    h_v, fin_v = forward_hidden(params, cfg, toks, schedule="diagonal")
    h_f, fin_f = forward_hidden(params, cfg, toks, schedule="diagonal",
                                grouped_impl="fused")
    _allclose(h_f, h_v)
    _allclose(fin_f, fin_v)
    # cfg-level knob routes identically to the argument override
    cfg2 = dataclasses.replace(cfg, grouped_impl="fused")
    h_c, _ = forward_hidden(params, cfg2, toks, schedule="diagonal")
    _allclose(h_c, h_f)


def test_serve_engine_fused_prefill():
    """ServeEngine(grouped_impl='fused') produces the same prefill logits and
    decode state as the vmap engine."""
    from repro.serve import ServeEngine
    cfg, params, toks = _setup("llama-1b-armt", S=3, B=1)
    eng_v = ServeEngine(params, cfg, serve_mode="armt", schedule="diagonal",
                        max_len=256)
    eng_f = ServeEngine(params, cfg, serve_mode="armt", schedule="diagonal",
                        max_len=256, grouped_impl="fused")
    lg_v, st_v = eng_v.prefill(toks)
    lg_f, st_f = eng_f.prefill(toks)
    _allclose(lg_f, lg_v)
    _allclose(st_f, st_v)
