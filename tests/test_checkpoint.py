"""Checkpointing: roundtrip, atomicity, keep-k GC, corruption detection,
resume."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(key, scale=1.0):
    ks = jax.random.split(key, 3)
    return {"a": jax.random.normal(ks[0], (4, 8)) * scale,
            "nested": {"b": jax.random.normal(ks[1], (3,)) * scale,
                       "t": (jax.random.normal(ks[2], (2, 2)),
                             jnp.zeros((), jnp.int32))}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    tree = _tree(jax.random.PRNGKey(0))
    mgr.save(7, tree)
    got = mgr.restore(tree)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)), tree, got)
    assert mgr.latest_step() == 7


def test_keep_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    tree = _tree(jax.random.PRNGKey(0))
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_corruption_detected(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    tree = _tree(jax.random.PRNGKey(0))
    mgr.save(1, tree)
    # flip bytes in one leaf
    leaf = next((tmp_path / "step_1").glob("leaf_0.npy"))
    arr = np.load(leaf)
    arr.ravel()[0] += 1
    np.save(leaf, arr)
    with pytest.raises(IOError, match="corruption"):
        mgr.restore(tree)


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    tree = _tree(jax.random.PRNGKey(1))
    mgr.save(5, tree)
    mgr.wait()
    got = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(tree["a"]), np.asarray(got["a"]))


def test_restore_latest_of_many(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=10, async_save=False)
    for s in (10, 20, 30):
        mgr.save(s, _tree(jax.random.PRNGKey(s), scale=float(s)))
    got = mgr.restore(_tree(jax.random.PRNGKey(0)))
    want = _tree(jax.random.PRNGKey(30), scale=30.0)
    np.testing.assert_allclose(np.asarray(got["a"]), np.asarray(want["a"]))
