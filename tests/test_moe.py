"""MoE: argsort dispatch correctness vs a dense (compute-all-experts)
reference, capacity behaviour, shared expert, load-balance loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test extra ([test] in pyproject)
from hypothesis import given, settings, strategies as st

from repro.configs import MoEConfig
from repro.models.moe import (aux_load_balance_loss, capacity, moe_ffn,
                              moe_param_init)


def _dense_reference(x, p, mcfg):
    """Compute every expert for every token; combine with top-k gates."""
    B, T, D = x.shape
    N = B * T
    xf = x.reshape(N, D)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, eidx = jax.lax.top_k(probs, mcfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    g = jnp.einsum("nd,edf->enf", xf, p["wg"])
    u = jnp.einsum("nd,edf->enf", xf, p["wu"])
    all_out = jnp.einsum("enf,efd->end", jax.nn.silu(g) * u, p["wd"])
    onehot = jax.nn.one_hot(eidx, mcfg.n_experts)           # [N,K,E]
    y = jnp.einsum("nke,end,nk->nd", onehot, all_out, gate)
    if "shared" in p:
        from repro.models.layers import ffn
        y = y + ffn("silu", xf, p["shared"])
    return y.reshape(B, T, D)


@given(st.integers(0, 5))
@settings(max_examples=8, deadline=None)
def test_dispatch_matches_dense_reference(seed):
    mcfg = MoEConfig(n_experts=8, top_k=2, d_expert=16, d_shared=16,
                     capacity_factor=8.0)     # high cf: no drops
    D = 12
    p = moe_param_init(jax.random.PRNGKey(seed), D, mcfg, "silu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 100), (2, 10, D))
    got = moe_ffn(x, p, mcfg, "silu")
    want = _dense_reference(x, p, mcfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_capacity_drops_tokens_but_stays_finite():
    mcfg = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=0.25)
    D = 8
    p = moe_param_init(jax.random.PRNGKey(0), D, mcfg, "silu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, D))
    y = moe_ffn(x, p, mcfg, "silu")
    assert np.isfinite(np.asarray(y)).all()


def test_capacity_formula():
    mcfg = MoEConfig(n_experts=384, top_k=8, d_expert=2048,
                     capacity_factor=1.25)
    c = capacity(36864, mcfg)
    assert c >= 36864 * 8 * 1.25 / 384 - 8
    assert c % 8 == 0


def test_load_balance_loss_uniform_router_is_one():
    mcfg = MoEConfig(n_experts=8, top_k=2, d_expert=8)
    D = 8
    p = moe_param_init(jax.random.PRNGKey(0), D, mcfg, "silu", jnp.float32)
    p = dict(p, router=jnp.zeros((D, 8)))     # uniform routing
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, D))
    l = float(aux_load_balance_loss(x, p, mcfg))
    assert abs(l - 1.0) < 0.2


def test_moe_grads_reach_experts():
    mcfg = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=4.0)
    D = 8
    p = moe_param_init(jax.random.PRNGKey(0), D, mcfg, "silu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, D))
    g = jax.grad(lambda pp: jnp.sum(moe_ffn(x, pp, mcfg, "silu") ** 2))(p)
    assert float(jnp.abs(g["wg"]).max()) > 0
    assert float(jnp.abs(g["router"]).max()) > 0


@given(st.integers(0, 4))
@settings(max_examples=6, deadline=None)
def test_einsum_dispatch_matches_argsort(seed):
    """The sharding-transparent einsum dispatch (iterative-argmax top-k +
    cumsum positions) must equal the argsort path when dropless."""
    mcfg = MoEConfig(n_experts=8, top_k=2, d_expert=16, d_shared=16,
                     capacity_factor=8.0)
    mcfg_e = dataclasses.replace(mcfg, dispatch="einsum")
    D = 12
    p = moe_param_init(jax.random.PRNGKey(seed), D, mcfg, "silu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 50), (3, 10, D))
    y1 = moe_ffn(x, p, mcfg, "silu")
    y2 = moe_ffn(x, p, mcfg_e, "silu")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-5, rtol=2e-4)


def test_einsum_dispatch_capacity_drops_finite():
    mcfg = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=0.25,
                     dispatch="einsum")
    D = 8
    p = moe_param_init(jax.random.PRNGKey(0), D, mcfg, "silu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, D))
    y = moe_ffn(x, p, mcfg, "silu")
    assert np.isfinite(np.asarray(y)).all()
