"""Extra integration coverage: Pallas attention inside the model, decode
smoke for every assigned arch, elastic checkpoint resharding."""
import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.models import (decode_state_init, decode_step, encode, init_params)
from repro.models.model import _fill_cross_kv


def test_model_pallas_attention_matches_dense():
    from repro.models import forward_hidden
    cfg = get_smoke_config("h2o-danube-1.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 8, cfg.vocab)
    h1, _ = forward_hidden(params, cfg, toks, seg_len=16)
    cfgp = dataclasses.replace(cfg, attn_impl="pallas")
    h2, _ = forward_hidden(params, cfgp, toks, seg_len=16)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_smoke_every_arch(arch):
    """One ARMT/SSM-mode decode step per assigned architecture."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    st = decode_state_init(cfg, B, serve_mode="armt", max_len=64,
                           dtype=jnp.float32)
    if cfg.encoder is not None:
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.encoder.n_frames, cfg.d_model))
        eo = encode(params, cfg, frames)
        sub = _fill_cross_kv(params, cfg,
                             {"prelude": st["prelude"],
                              "pattern": st["pattern"]}, eo)
        st = {**st, **sub}
    toks = jax.random.randint(jax.random.PRNGKey(1), (B,), 8, cfg.vocab)
    logits, st2 = decode_step(params, cfg, st, toks, serve_mode="armt")
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch
    assert int(st2["pos"]) == 1


_ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.models import init_params
from repro.models.model import param_specs
from repro.parallel import sharding as shd

cfg = get_smoke_config("qwen2.5-32b")
params = init_params(cfg, jax.random.PRNGKey(0))
d = tempfile.mkdtemp()
mgr = CheckpointManager(d, async_save=False)
mgr.save(1, params)                       # saved from single-device layout

# restore RESHARDED onto a 2x4 mesh (elastic restart on a new topology)
mesh = jax.make_mesh((2, 4), ("data", "model"))
with mesh:
    specs = shd.param_specs(jax.eval_shape(lambda: params), mesh)
    restored = mgr.restore(params, shardings=specs)
leaf = jax.tree_util.tree_leaves(restored)[0]
ok = np.allclose(np.asarray(leaf), np.asarray(jax.tree_util.tree_leaves(params)[0]))
n_shards = len(leaf.sharding.device_set)
print("ELASTIC_OK", ok, n_shards)
assert ok and n_shards == 8
"""


def test_elastic_resharding_restore():
    r = subprocess.run([sys.executable, "-c", _ELASTIC_SCRIPT],
                       capture_output=True, text=True, timeout=420,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "ELASTIC_OK True" in r.stdout, (r.stdout[-400:], r.stderr[-1200:])
