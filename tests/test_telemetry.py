"""Serve-stack telemetry (DESIGN.md §13): metrics registry semantics,
Chrome-trace schema, recorder-derived serving metrics vs the pre-PR-7
bench reference implementations, the one-host-transfer-per-chunk
invariant with telemetry enabled, the jit-compile budget over mixed
prompt lengths, and the sharding-fallback counter unification."""
import json
import logging

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve import (ContinuousScheduler, MetricsRegistry, PrefixCache,
                         Request, ServeEngine, Telemetry, TraceRecorder,
                         default_registry, validate_chrome_trace)
from repro.serve.telemetry import SPAN_CATEGORIES, _main as telemetry_cli


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("h2o-danube-1.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _toks(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(8, cfg.vocab, (n,)).astype(np.int32)


def _requests(cfg, lens, max_new, seed=0):
    return [Request(req_id=f"r{i}", prompt=_toks(cfg, L, seed=seed + i),
                    max_new=max_new)
            for i, L in enumerate(lens)]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_histograms():
    reg = MetricsRegistry()
    reg.inc("reqs_total")
    reg.inc("reqs_total", 2)
    reg.inc("reqs_total", result="hit")
    reg.inc("reqs_total", result="hit")
    reg.inc("reqs_total", result="miss")
    reg.set_gauge("occupancy", 3)
    reg.set_gauge("occupancy", 5)                       # gauges overwrite
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.observe("wait_s", v)
    snap = reg.snapshot()
    assert snap["counters"]["reqs_total"] == 3
    assert snap["counters"]["reqs_total{result=hit}"] == 2
    assert snap["counters"]["reqs_total{result=miss}"] == 1
    assert snap["gauges"]["occupancy"] == 5
    h = snap["histograms"]["wait_s"]
    assert h["count"] == 4 and h["sum"] == 10.0 and h["max"] == 4.0
    assert h["p50"] == 2.5
    # the snapshot is JSON-able as-is (the artifact contract)
    json.dumps(snap)


def test_registry_remove_series_and_reset_hooks():
    reg = MetricsRegistry()
    reg.inc("fallbacks", kind="param", dim=1)
    reg.inc("fallbacks", kind="state", dim=2)
    reg.inc("other")
    reg.remove_series("fallbacks")
    assert reg.counters == {"other": 1}
    fired = []
    reg.register_reset_hook(lambda: fired.append(1))
    reg.register_reset_hook(lambda: fired.append(1))    # dedup is by identity
    reg.reset()
    assert reg.counters == {} and len(fired) >= 1


def test_registry_probes_sampled_at_snapshot():
    reg = MetricsRegistry()
    state = {"n": 0}
    reg.register_probe("live", lambda: state["n"])
    reg.register_probe("broken", lambda: 1 / 0)
    state["n"] = 7
    snap = reg.snapshot()
    assert snap["probes"]["live"] == 7                  # sampled now, not at
    assert "error" in snap["probes"]["broken"]          # registration time


# ---------------------------------------------------------------------------
# Trace schema
# ---------------------------------------------------------------------------

def test_chrome_trace_schema_valid_and_lanes():
    rec = TraceRecorder(t0=0.0)
    with rec.span("decode_chunk", "decode", steps=4):
        pass
    rec.add_span("admission", "admission", 0.1, 0.2, lane="r0", slot=1)
    rec.instant("segment_flush", "flush", t=0.15, lane="r0")
    rec.emit("r0", 0.2, 3)
    trace = rec.chrome_trace()
    assert validate_chrome_trace(trace) == []
    names = {e["args"]["name"] for e in trace["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"scheduler", "req:r0"} <= names
    # every non-metadata event carries a known category
    assert all(e.get("cat") in SPAN_CATEGORIES
               for e in trace["traceEvents"] if e["ph"] in ("X", "i"))


def test_chrome_trace_schema_rejects_malformed():
    assert validate_chrome_trace({"nope": 1})
    bad = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 0, "name": "x", "cat": "decode",
         "ts": 0.0, "dur": -1.0},                       # negative duration
        {"ph": "i", "pid": 1, "tid": 0, "name": "y", "cat": "not-a-cat",
         "ts": 1.0},                                    # unknown category
        {"ph": "Z", "pid": 1, "tid": 0, "name": "z"},   # unknown phase
    ]}
    errs = validate_chrome_trace(bad)
    assert any("dur" in e for e in errs)
    assert any("not-a-cat" in e for e in errs)
    assert any("ph" in e for e in errs)
    assert any("thread_name" in e for e in errs)        # tid 0 never named


def test_telemetry_cli_gate(tmp_path):
    rec = TraceRecorder(t0=0.0)
    with rec.span("decode_chunk", "decode"):
        pass
    rec.instant("segment_flush", "flush", t=0.1)
    path = str(tmp_path / "trace.json")
    rec.export(path)
    assert telemetry_cli([path, "--require-cats", "decode,flush"]) == 0
    # instants alone satisfy a category, but a missing one still fails
    assert telemetry_cli([path, "--require-cats", "decode,session"]) == 1
    assert telemetry_cli([path, "--min-spans", "5"]) == 1


# ---------------------------------------------------------------------------
# Derived serving metrics == the pre-PR-7 bench reference implementations
# ---------------------------------------------------------------------------
# Verbatim copies of benchmarks/bench_serve.py's deleted helpers: the old
# path scanned per-token StreamEvent.t_emit stamps; the recorder stores one
# (t, n) entry per (request, chunk). The derivations must agree exactly.

def _ref_itl_stats(emit_times):
    itls = []
    for times in emit_times.values():
        itls += [b - a for a, b in zip(times, times[1:])]
    if not itls:
        return 0.0, 0.0
    return (float(np.percentile(itls, 50)), float(np.percentile(itls, 99)))


def _ref_admission_stall(windows, emit_times):
    times = sorted({t for ts in emit_times.values() for t in ts})
    gaps = [(a, b) for a, b in zip(times, times[1:])]
    stall = 0.0
    for (w0, w1) in windows:
        for (a, b) in gaps:
            if a <= w1 and b >= w0:
                stall = max(stall, b - a)
    return stall


def test_derivations_match_reference_synthetic():
    chunks = {"a": [(0.00, 3), (0.10, 3), (0.50, 2)],
              "b": [(0.05, 1), (0.60, 4)],
              "c": [(0.70, 1)]}                  # single chunk: no ITL at all
    windows = [(0.08, 0.45), (0.55, 0.58)]
    rec = TraceRecorder(t0=0.0)
    for rid, cs in chunks.items():
        for t, n in cs:
            rec.emit(rid, t, n)
    for (w0, w1) in windows:
        rec.add_span("admission", "admission", w0, w1)
    # the old per-token view: every token of a chunk shares its stamp
    emit_times = {rid: [t for (t, n) in cs for _ in range(n)]
                  for rid, cs in chunks.items()}
    assert sorted(rec.itl_values()) == sorted(
        [b - a for ts in emit_times.values() for a, b in zip(ts, ts[1:])])
    assert rec.itl_percentiles() == _ref_itl_stats(emit_times)
    assert rec.admission_stall_s() == pytest.approx(
        _ref_admission_stall(windows, emit_times))
    assert rec.admission_windows() == windows


def test_derivations_match_reference_live_run(setup):
    """A real scheduler run: the recorder's ITL percentiles and admission
    stall equal the old bench derivation applied to the per-token
    ``StreamEvent.t_emit`` stream + ``sched.admission_windows`` — the
    agreement that justified deleting the bench-local scan."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      telemetry=Telemetry(trace=True,
                                          registry=MetricsRegistry()))
    reqs = _requests(cfg, [seg, seg + seg // 2, seg, seg + seg // 2, seg],
                     max_new=10)
    sched = ContinuousScheduler(eng, n_slots=2, chunk=4,
                                max_concurrent_admissions=2)
    emit_times = {}
    for ev in sched.run(iter(reqs)):
        emit_times.setdefault(ev.req_id, []).append(ev.t_emit)
    rec = eng.telemetry.trace
    assert rec.itl_percentiles() == _ref_itl_stats(emit_times)
    assert rec.admission_stall_s() == pytest.approx(
        _ref_admission_stall(sched.admission_windows, emit_times))
    # the recorder's windows ARE the scheduler's (same stamps)
    assert rec.admission_windows() == sched.admission_windows


# ---------------------------------------------------------------------------
# Span coverage + schema on a live serve run
# ---------------------------------------------------------------------------

def test_serve_run_span_coverage_and_counters(setup):
    cfg, params = setup
    seg = cfg.armt.segment_len
    tel = Telemetry(trace=True, registry=MetricsRegistry())
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      telemetry=tel)
    # first request is long and admissions advance one group per round, so
    # the cold pool drains it in the idle tight loop; max_new crosses a
    # segment boundary so in-graph flushes surface as host instants
    reqs = _requests(cfg, [6 * seg, seg + seg // 2, seg, seg + seg // 2, seg],
                     max_new=seg + 2)
    sched = ContinuousScheduler(eng, n_slots=2, chunk=4,
                                prefill_groups_per_chunk=1,
                                max_concurrent_admissions=4)
    n_tok = sum(1 for _ in sched.run(iter(reqs)))
    assert n_tok == 5 * (seg + 2)
    trace = tel.trace.chrome_trace()
    assert validate_chrome_trace(trace) == []
    cats = {e.get("cat") for e in trace["traceEvents"]
            if e.get("ph") in ("X", "i")}
    # decode chunks, admission windows+rounds, transplants, host-derived
    # segment flushes, idle-drain rounds and per-chunk token emits all
    # present on one burst-y run
    assert {"decode", "admission", "transplant", "flush", "idle",
            "emit"} <= cats
    snap = tel.registry.snapshot()
    assert snap["counters"]["admissions_total"] == 5
    assert snap["counters"]["decode_flushes_total"] == 5   # one per request
    # the gauge is sampled at chunk boundaries (before the chunk's tokens
    # free any slot), so the last sample still shows the final occupant
    assert 1 <= snap["gauges"]["pool_occupancy"] <= 2
    assert snap["histograms"]["chunk_queue_depth"]["count"] > 0
    assert snap["histograms"]["queue_wait_s"]["count"] == 5
    # per-request lanes: every request got its own named thread
    lane_names = {e["args"]["name"] for e in trace["traceEvents"]
                  if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {f"req:r{i}" for i in range(5)} <= lane_names


# ---------------------------------------------------------------------------
# Zero-sync: one host transfer per chunk, telemetry fully on
# ---------------------------------------------------------------------------

class _CountingNp:
    """numpy proxy counting ``asarray`` calls whose argument is a device
    array — i.e. actual device->host transfers issued by the scheduler."""

    def __init__(self, real):
        self._real = real
        self.device_transfers = 0

    def __getattr__(self, name):
        return getattr(self._real, name)

    def asarray(self, x, *a, **kw):
        if isinstance(x, jax.Array):
            self.device_transfers += 1
        return self._real.asarray(x, *a, **kw)


def test_one_host_transfer_per_chunk_with_telemetry(setup, monkeypatch):
    """The telemetry hard constraint, regression-tested: with trace +
    metrics fully enabled, the scheduler performs exactly TWO
    device->host conversions per decode chunk (the token block and the
    mask block that always existed) — emit stamps, flush instants and
    gauges are all derived from those host copies."""
    import repro.serve.scheduler as sched_mod
    cfg, params = setup
    seg = cfg.armt.segment_len
    proxy = _CountingNp(np)
    monkeypatch.setattr(sched_mod, "np", proxy)
    tel = Telemetry(trace=True, registry=MetricsRegistry())
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      telemetry=tel)
    reqs = _requests(cfg, [seg, seg + seg // 2, seg], max_new=seg + 2)
    sched = ContinuousScheduler(eng, n_slots=2, chunk=4)
    n_tok = sum(1 for _ in sched.run(iter(reqs)))
    assert n_tok == 3 * (seg + 2)
    n_chunks = sum(1 for s in tel.trace.spans if s.name == "decode_chunk")
    assert n_chunks > 0
    assert proxy.device_transfers == 2 * n_chunks


# ---------------------------------------------------------------------------
# Compile budget over mixed prompt lengths (the O(log) claim, measured)
# ---------------------------------------------------------------------------

def test_compile_budget_mixed_prompt_lengths(setup):
    """pow2 bucketing: prompts spanning many lengths share O(log)
    compiled programs — the engine's jit caches grow with the number of
    DISTINCT pow2 buckets, and a second wave of new lengths inside the
    same buckets adds zero entries."""
    cfg, params = setup
    seg = cfg.armt.segment_len
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=512,
                      telemetry=Telemetry(trace=False,
                                          registry=MetricsRegistry()))
    def run(lens, seed):
        sched = ContinuousScheduler(eng, n_slots=2, chunk=4)
        for _ in sched.run(iter(_requests(cfg, lens, 4, seed=seed))):
            pass
    # 6 distinct lengths covering the pow2 buckets: full-segment diagonal
    # groups {1, 2, 4} and descending-pow2 tail pieces {16, 8, 4, 2, 1}
    # (a 31-token tail decomposes into all five)
    run([seg, seg + 31, 2 * seg, 2 * seg + seg // 2,
         3 * seg, 4 * seg], seed=0)
    budget = eng.compile_counts()
    # new lengths inside the same buckets (tails decompose into already-
    # compiled pieces, segment counts stay <= 4): nothing recompiles
    run([seg + 12, 2 * seg + 9, 3 * seg + 16, 2 * seg + 11], seed=9)
    after = eng.compile_counts()
    assert after == budget, (budget, after)
    # the whole mixed workload fits an O(log) program budget: at most
    # log2(seg)+1 tail-piece steppers plus per-bucket scheduler/prefill
    # entries, far below one-program-per-length (10 distinct lengths)
    assert after["decode_step"] <= seg.bit_length(), after
    assert after["total"] <= 16, after
    assert after["scheduler_fns"] <= 4                  # <= 1 + #buckets


def test_generation_result_metrics(setup):
    cfg, params = setup
    seg = cfg.armt.segment_len
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                      telemetry=Telemetry(registry=MetricsRegistry()),
                      prefix_cache=PrefixCache(seg, max_bytes=1 << 20))
    res = eng.generate(_toks(cfg, seg)[None], 4)
    assert res.metrics is not None
    probes = res.metrics["probes"]
    assert probes["engine_compile_counts"]["total"] >= 1
    assert probes["prefix_cache"]["misses"] >= 0
    assert "prefix_probe_total{result=miss}" in res.metrics["counters"]
    assert "generate_ttft_s" in res.metrics["histograms"]
    # disabled telemetry: no snapshot, generation still works
    eng_off = ServeEngine(params, cfg, serve_mode="armt", max_len=256,
                          telemetry=Telemetry.disabled())
    res_off = eng_off.generate(_toks(cfg, seg)[None], 4)
    assert res_off.metrics is None
    np.testing.assert_array_equal(np.asarray(res.tokens),
                                  np.asarray(res_off.tokens))


def test_disabled_telemetry_is_noop():
    tel = Telemetry.disabled()
    assert not tel.on and tel.snapshot() is None
    tel.inc("x")
    tel.observe("y", 1.0)
    tel.set_gauge("z", 2.0)
    tel.add_span("a", "decode", 0.0, 1.0)
    tel.instant("b", "flush")
    tel.emit("r", 0.0, 1)
    with tel.span("c", "decode"):
        pass
    tel.sample_device_memory()


# ---------------------------------------------------------------------------
# Sharding fallbacks route through the registry (satellite)
# ---------------------------------------------------------------------------

def test_sharding_fallback_counter_and_unified_reset(caplog):
    from repro.parallel import sharding as shd
    shd.reset_fallback_warnings()
    reg = default_registry()

    def count():
        return sum(v for k, v in reg.counters.items()
                   if k.startswith("sharding_fallback_total"))

    with caplog.at_level(logging.WARNING, logger="repro.parallel.sharding"):
        shd.param_leaf_spec(["pattern", "attn", "wq"], (30, 30), 16)
        shd.param_leaf_spec(["pattern", "attn", "wq"], (30, 30), 16)
    # the log line stays deduped (one line per distinct fallback) but the
    # counter counts every occurrence
    recs = [r for r in caplog.records if "sharding-fallback" in r.getMessage()]
    assert len(recs) == 1
    assert count() == 2
    key = [k for k in reg.counters
           if k.startswith("sharding_fallback_total")][0]
    assert "kind=param" in key and "leaf=pattern.attn.wq" in key \
        and "dim=1" in key and "axis=model" in key
    # one reset clears both views...
    shd.reset_fallback_warnings()
    assert count() == 0
    with caplog.at_level(logging.WARNING, logger="repro.parallel.sharding"):
        shd.param_leaf_spec(["pattern", "attn", "wq"], (30, 30), 16)
    assert len([r for r in caplog.records
                if "sharding-fallback" in r.getMessage()]) == 2
    # ...and so does the registry's own reset (the dedup set is a hook)
    reg.reset()
    assert count() == 0
    with caplog.at_level(logging.WARNING, logger="repro.parallel.sharding"):
        shd.param_leaf_spec(["pattern", "attn", "wq"], (30, 30), 16)
    assert len([r for r in caplog.records
                if "sharding-fallback" in r.getMessage()]) == 3
    shd.reset_fallback_warnings()
