"""Property sweeps for every kernel: f32/bf16 × non-aligned shapes (odd
M/N/K, head_dim not a multiple of the block, ragged tile edges forced via
small explicit block configs) against the kernels/ref.py oracles — plus
the masked-vs-skipped equivalence gate for the sliding-window block-skip
bounds (must be BITWISE: a skipped block that wasn't fully masked would
show up as a real difference, not rounding)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.dispatch import KernelConfig
from repro.kernels.flash_attention import flash_attention

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 5e-2}
DTYPES = [jnp.float32, jnp.bfloat16]


def _close(a, b, dtype):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        atol=ATOL[dtype], rtol=ATOL[dtype] * 10)


def _pcfg(**blocks):
    return KernelConfig(impl="pallas", interpret=True, **blocks)


# ------------------------------------------------------------- grouped GEMM

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("G,M,K,N", [(3, 37, 29, 53), (2, 17, 160, 96),
                                     (1, 63, 31, 65)])
def test_grouped_matmul_nonaligned(G, M, K, N, dtype):
    """Odd M/N/K with 16-wide blocks: every grid edge is ragged."""
    ks = jax.random.split(jax.random.PRNGKey(M * N + K), 3)
    x = jax.random.normal(ks[0], (G, M, K), dtype)
    w = jax.random.normal(ks[1], (G, K, N), dtype)
    b = jax.random.normal(ks[2], (G, N), dtype)
    out = ops.grouped_gemm(x, w, b, activation="silu",
                           config=_pcfg(block_m=16, block_n=16, block_k=16))
    _close(out, ref.grouped_matmul_ref(x, w, b, activation="silu"), dtype)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("R,K,D,M,block_m", [
    (19, 13, 24, 3, 8),     # ragged last tile, mem rows inside it
    (24, 32, 16, 4, 256),   # single tile
    (19, 13, 24, 4, 8),     # mem rows straddle -> ops falls back, still ok
])
def test_grouped_matmul_armt_update_nonaligned(R, K, D, M, block_m, dtype):
    """The fused ARMT-epilogue GEMM across ragged tiles and the
    constraint-violating fallback path."""
    G, dm, nu = 2, 4, 3
    P = 2 * nu * dm
    ks = jax.random.split(jax.random.PRNGKey(R + D), 9)
    x = (jax.random.normal(ks[0], (G, R, K)) * 0.3).astype(dtype)
    w = (jax.random.normal(ks[1], (G, K, D)) * 0.3).astype(dtype)
    res = (jax.random.normal(ks[2], (G, R, D)) * 0.3).astype(dtype)
    wk = (jax.random.normal(ks[3], (G, D, dm)) * 0.3).astype(dtype)
    wv = (jax.random.normal(ks[4], (G, D, D)) * 0.3).astype(dtype)
    wb = (jax.random.normal(ks[5], (G, D, 1)) * 0.3).astype(dtype)
    A = jax.random.normal(ks[6], (G, P, D)) * 0.1
    z = jax.random.normal(ks[7], (G, P)) * 0.1
    bias = (jax.random.normal(ks[8], (G, D)) * 0.3).astype(dtype)
    got = ops.grouped_gemm_armt_update(
        x, w, res, wk, wv, wb, A, z, bias, M=M, nu=nu,
        config=_pcfg(block_m=block_m, block_k=8))
    want = ref.grouped_matmul_armt_update_ref(x, w, res, wk, wv, wb, A, z,
                                              bias, M=M, nu=nu)
    for g, r in zip(got, want):
        _close(g, r, dtype)


# ---------------------------------------------------------------- attention

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("Hq,Hkv,T,hd,causal,window", [
    (4, 2, 37, 24, True, 0),     # GQA, ragged T, hd needs 128-pad
    (3, 1, 29, 40, True, 11),    # MQA + window, odd everything
    (2, 2, 33, 24, False, 9),    # symmetric (non-causal) window
])
def test_flash_attention_nonaligned(Hq, Hkv, T, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(T * hd), 3)
    q = jax.random.normal(ks[0], (2, Hq, T, hd), dtype)
    k = jax.random.normal(ks[1], (2, Hkv, T, hd), dtype)
    v = jax.random.normal(ks[2], (2, Hkv, T, hd), dtype)
    out = ops.segment_attention(q, k, v, causal=causal, window=window,
                                config=_pcfg(block_q=16, block_k=16))
    _close(out, ref.flash_attention_ref(q, k, v, causal=causal,
                                        window=window), dtype)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("Hq,Hkv,S,hd,window", [
    (4, 2, 37, 24, 0), (2, 1, 61, 16, 0), (4, 4, 45, 24, 7),
])
def test_decode_attention_nonaligned(Hq, Hkv, S, hd, window, dtype):
    """Single-token decode kernel: ragged cache lengths per row, GQA,
    non-128 head dim (padded by the ops wrapper)."""
    B = 3
    ks = jax.random.split(jax.random.PRNGKey(S + hd), 3)
    q = jax.random.normal(ks[0], (B, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    lens = jnp.array([1, S // 2 + 1, S], jnp.int32)
    out = ops.decode_attention(q, k, v, lens, window=window,
                               config=_pcfg(block_k=8))
    _close(out, ref.decode_attention_ref(q, k, v, lens, window=window),
           dtype)


# -------------------------------------------------------------- ARMT memory

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("T,D,dm,Dv,M", [(19, 24, 4, 40, 3),
                                         (33, 48, 8, 24, 5)])
def test_armt_kernels_nonaligned(T, D, dm, Dv, M, dtype):
    N, P = 3, 6 * dm
    ks = jax.random.split(jax.random.PRNGKey(T + Dv), 8)
    x = jax.random.normal(ks[0], (N, T, D), dtype)
    wq = (jax.random.normal(ks[1], (D, dm)) * 0.3).astype(dtype)
    A = jax.random.normal(ks[2], (N, P, Dv)) * 0.1
    z = jax.random.uniform(ks[3], (N, P))
    out = ops.assoc_read(x, wq, A, z, config=_pcfg(block_t=8, block_v=16))
    _close(out, ref.armt_read_ref(x, wq, A, z), dtype)

    m = jax.random.normal(ks[4], (N, M, D), dtype)
    wk = (jax.random.normal(ks[5], (D, dm)) * 0.3).astype(dtype)
    wv = (jax.random.normal(ks[6], (D, Dv)) * 0.3).astype(dtype)
    wb = (jax.random.normal(ks[7], (D, 1)) * 0.3).astype(dtype)
    A2, z2 = ops.assoc_update(m, wk, wv, wb, A, z, config=_pcfg(block_v=16))
    Ar, zr = ref.armt_update_ref(m, wk, wv, wb, A, z)
    _close(A2, Ar, dtype)
    _close(z2, zr, dtype)


# --------------------------------------------------------------- mamba scan

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("T,dI,dS", [(9, 24, 4), (17, 40, 8)])
def test_mamba_scan_nonaligned(T, dI, dS, dtype):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(T + dI), 5)
    x = (jax.random.normal(ks[0], (B, T, dI)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, dI))).astype(dtype)
    Bt = (jax.random.normal(ks[2], (B, T, dS)) * 0.5).astype(dtype)
    Ct = (jax.random.normal(ks[3], (B, T, dS)) * 0.5).astype(dtype)
    A_log = jnp.log(jnp.tile(jnp.arange(1., dS + 1)[None], (dI, 1)))
    D = jnp.ones(dI)
    h0 = jax.random.normal(ks[4], (B, dI, dS)) * 0.1
    y, hT = ops.selective_scan_fused(x, dt, Bt, Ct, A_log, D, h0,
                                     config=_pcfg(block_i=16))
    yr, hr = ref.mamba_scan_ref(x, dt, Bt, Ct, A_log, D, h0)
    _close(y, yr, dtype)
    _close(hT, hr, dtype)


# ------------------------------------------- masked-vs-skipped equivalence

@pytest.mark.parametrize("causal,window", [
    (True, 0), (True, 24), (True, 7), (False, 24), (False, 7),
])
def test_window_skip_equals_mask(causal, window):
    """The block-skip bounds (causal diagonal, window lower bound, and the
    new non-causal window *upper* bound) must be pure work elimination:
    skip_blocks=True and =False agree BITWISE, ragged shapes included."""
    ks = jax.random.split(jax.random.PRNGKey(window + causal), 3)
    q = jax.random.normal(ks[0], (1, 2, 200, 128))
    k = jax.random.normal(ks[1], (1, 2, 200, 128))
    v = jax.random.normal(ks[2], (1, 2, 200, 128))
    skip = flash_attention(q, k, v, causal=causal, window=window,
                           block_q=32, block_k=16, interpret=True,
                           skip_blocks=True)
    mask = flash_attention(q, k, v, causal=causal, window=window,
                           block_q=32, block_k=16, interpret=True,
                           skip_blocks=False)
    np.testing.assert_array_equal(np.asarray(skip), np.asarray(mask))
    _close(skip, ref.flash_attention_ref(q, k, v, causal=causal,
                                         window=window), jnp.float32)


def test_decode_skip_equals_full_scan():
    """The decode kernel's dynamic length bound reads fewer tiles but must
    match the oracle that sees (and masks) the whole cache."""
    B, Hq, Hkv, S, hd = 2, 2, 2, 128, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, Hq, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    lens = jnp.array([5, 77], jnp.int32)
    out = ops.decode_attention(q, k, v, lens, config=_pcfg(block_k=16))
    _close(out, ref.decode_attention_ref(q, k, v, lens), jnp.float32)
