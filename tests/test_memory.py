"""ARMT associative memory unit + property tests (eqs. 3-6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test extra ([test] in pyproject)
from hypothesis import given, settings, strategies as st

from repro.configs import ARMTConfig
from repro.core import dpfp, d_phi, mem_param_init, mem_read, mem_state_init, mem_update


def test_dpfp_shape_and_nonneg():
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 7))
    for nu in (1, 2, 3):
        y = dpfp(x, nu)
        assert y.shape == (5, 2 * nu * 7)
        assert (np.asarray(y) >= 0).all()


@given(st.integers(1, 4), st.integers(2, 8))
@settings(max_examples=10, deadline=None)
def test_dpfp_batch_consistency(b, d):
    """dpfp is applied elementwise over leading dims."""
    x = jax.random.normal(jax.random.PRNGKey(b * 31 + d), (b, d))
    y = dpfp(x, 3)
    y0 = dpfp(x[0], 3)
    assert np.allclose(np.asarray(y[0]), np.asarray(y0), atol=1e-6)


def _setup(d_model=16, d_mem=4, batch=2):
    acfg = ARMTConfig(segment_len=8, num_mem_tokens=4, d_mem=d_mem)
    params = mem_param_init(jax.random.PRNGKey(0), d_model, acfg)
    state = mem_state_init(batch, d_model, acfg)
    return acfg, params, state


def test_zero_state_reads_zero():
    """eq 3: A_0 = z_0 = 0 -> read returns 0 (eps-guarded division)."""
    acfg, params, state = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    r = mem_read(params, state, x, acfg)
    assert np.allclose(np.asarray(r), 0.0)
    assert not np.isnan(np.asarray(r)).any()


def test_update_then_read_retrieves():
    """Delta rule: after storing memory tokens m, reading with x whose query
    projection matches a stored key returns (approximately) its value —
    retrieval correlation must beat a random-query baseline."""
    acfg, params, state = _setup(d_model=32, d_mem=8)
    m = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 32))
    st1 = mem_update(params, state, m, acfg)
    # craft x so that W_Q x = W_K m (query matches stored key)
    q_target = jnp.einsum("bmd,de->bme", m, params["wk"])
    # least squares: x = q_target @ pinv(W_Q)
    x = jnp.einsum("bme,ed->bmd", q_target, jnp.linalg.pinv(params["wq"]))
    read = mem_read(params, st1, x, acfg)
    v = jnp.einsum("bmd,dv->bmv", m, params["wv"])
    # correlation between retrieved and stored values
    corr = np.corrcoef(np.asarray(read).ravel(), np.asarray(v).ravel())[0, 1]
    rand = mem_read(params, st1,
                    jax.random.normal(jax.random.PRNGKey(3), x.shape), acfg)
    corr_rand = np.corrcoef(np.asarray(rand).ravel(), np.asarray(v).ravel())[0, 1]
    assert corr > 0.5 and corr > corr_rand + 0.2


def test_update_accumulates():
    acfg, params, state = _setup()
    m1 = jax.random.normal(jax.random.PRNGKey(4), (2, 4, 16))
    m2 = jax.random.normal(jax.random.PRNGKey(5), (2, 4, 16))
    s1 = mem_update(params, state, m1, acfg)
    s2 = mem_update(params, s1, m2, acfg)
    assert not np.allclose(np.asarray(s1["A"]), np.asarray(s2["A"]))
    assert np.isfinite(np.asarray(s2["A"])).all()
    assert np.isfinite(np.asarray(s2["z"])).all()


@given(st.integers(1, 3))
@settings(max_examples=5, deadline=None)
def test_states_stay_finite_many_segments(seed):
    acfg, params, state = _setup()
    key = jax.random.PRNGKey(seed)
    for i in range(10):
        m = jax.random.normal(jax.random.fold_in(key, i), (2, 4, 16))
        state = mem_update(params, state, m, acfg)
    x = jax.random.normal(key, (2, 8, 16))
    r = mem_read(params, state, x, acfg)
    assert np.isfinite(np.asarray(r)).all()
