"""Per-kernel allclose sweeps (interpret mode) against the ref.py oracles,
over shapes and dtypes, per the deliverable spec."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 5e-2}


def _close(a, b, dtype):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        atol=ATOL[dtype], rtol=ATOL[dtype] * 10)


# ---------------------------------------------------------------- flash attn
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N,Hq,Hkv,T,S,hd,causal,window", [
    (2, 4, 4, 32, 32, 16, True, 0),       # MHA causal
    (2, 4, 2, 64, 64, 32, True, 0),       # GQA
    (1, 8, 1, 33, 33, 8, True, 0),        # MQA, ragged T
    (2, 2, 2, 32, 32, 16, False, 0),      # bidirectional
    (1, 4, 4, 64, 64, 16, True, 16),      # sliding window
])
def test_flash_attention(N, Hq, Hkv, T, S, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(T + hd), 3)
    q = jax.random.normal(ks[0], (N, Hq, T, hd), dtype)
    k = jax.random.normal(ks[1], (N, Hkv, S, hd), dtype)
    v = jax.random.normal(ks[2], (N, Hkv, S, hd), dtype)
    out = ops.segment_attention(q, k, v, causal=causal, window=window,
                                use_kernel=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    _close(out, want, dtype)


# ---------------------------------------------------------------- grouped mm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("G,M,K,N", [(1, 16, 16, 16), (4, 96, 160, 224),
                                     (7, 128, 64, 128), (2, 256, 512, 128)])
def test_grouped_matmul(G, M, K, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(G * M + N), 2)
    x = jax.random.normal(ks[0], (G, M, K), dtype)
    w = jax.random.normal(ks[1], (G, K, N), dtype)
    out = ops.grouped_gemm(x, w, use_kernel=True, interpret=True)
    _close(out, ref.grouped_matmul_ref(x, w), dtype)


@pytest.mark.parametrize("activation", [None, "silu", "gelu"])
@pytest.mark.parametrize("with_bias", [False, True])
def test_grouped_matmul_epilogue(activation, with_bias):
    """Fused bias + activation epilogue == fp32 reference epilogue."""
    G, M, K, N = 3, 48, 64, 96
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    x = jax.random.normal(ks[0], (G, M, K))
    w = jax.random.normal(ks[1], (G, K, N))
    b = jax.random.normal(ks[2], (G, N)) if with_bias else None
    out = ops.grouped_gemm(x, w, b, activation=activation,
                           use_kernel=True, interpret=True)
    want = ref.grouped_matmul_ref(x, w, b, activation=activation)
    _close(out, want, jnp.float32)


def test_armt_grouped_weights():
    """Per-group projection weights [G,D,*] (N = G*batch) == running each
    group's shared-weight kernel separately."""
    G, B, T, D, dm, Dv, M = 2, 3, 16, 32, 8, 48, 4
    N, P = G * B, 6 * dm
    ks = jax.random.split(jax.random.PRNGKey(11), 8)
    x = jax.random.normal(ks[0], (N, T, D))
    wq = jax.random.normal(ks[1], (G, D, dm)) * 0.3
    A = jax.random.normal(ks[2], (N, P, Dv)) * 0.1
    z = jax.random.uniform(ks[3], (N, P))
    out = ops.assoc_read(x, wq, A, z, use_kernel=True, interpret=True)
    want = jnp.concatenate([
        ref.armt_read_ref(x[g * B:(g + 1) * B], wq[g],
                          A[g * B:(g + 1) * B], z[g * B:(g + 1) * B])
        for g in range(G)])
    _close(out, want, jnp.float32)

    m = jax.random.normal(ks[4], (N, M, D))
    wk = jax.random.normal(ks[5], (G, D, dm)) * 0.3
    wv = jax.random.normal(ks[6], (G, D, Dv)) * 0.3
    wb = jax.random.normal(ks[7], (G, D, 1)) * 0.3
    A2, z2 = ops.assoc_update(m, wk, wv, wb, A, z,
                              use_kernel=True, interpret=True)
    Ar, zr = ref.armt_update_ref(m, wk, wv, wb, A, z)
    _close(A2, Ar, jnp.float32)
    _close(z2, zr, jnp.float32)


def test_flash_attention_window_block_skip():
    """Sliding-window lower-bound skip: many k-blocks fully below the window
    must not change the result (small block_k forces multiple skips)."""
    from repro.kernels.flash_attention import flash_attention
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 16))
    k = jax.random.normal(ks[1], (1, 2, 256, 16))
    v = jax.random.normal(ks[2], (1, 2, 256, 16))
    out = flash_attention(q, k, v, causal=True, window=24,
                          block_q=64, block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=24)
    _close(out, want, jnp.float32)


# ---------------------------------------------------------------- armt
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("N,T,D,dm,Dv,M", [
    (2, 32, 48, 8, 48, 4), (1, 16, 32, 4, 64, 8), (3, 64, 64, 16, 32, 16)])
def test_armt_kernels(N, T, D, dm, Dv, M, dtype):
    P = 6 * dm
    ks = jax.random.split(jax.random.PRNGKey(N * T + D), 8)
    x = jax.random.normal(ks[0], (N, T, D), dtype)
    wq = jax.random.normal(ks[1], (D, dm), dtype) * 0.3
    A = jax.random.normal(ks[2], (N, P, Dv), jnp.float32) * 0.1
    z = jax.random.uniform(ks[3], (N, P), jnp.float32)
    out = ops.assoc_read(x, wq, A, z, use_kernel=True, interpret=True)
    _close(out, ref.armt_read_ref(x, wq, A, z), dtype)

    m = jax.random.normal(ks[4], (N, M, D), dtype)
    wk = jax.random.normal(ks[5], (D, dm), dtype) * 0.3
    wv = jax.random.normal(ks[6], (D, Dv), dtype) * 0.3
    wb = jax.random.normal(ks[7], (D, 1), dtype) * 0.3
    A2, z2 = ops.assoc_update(m, wk, wv, wb, A, z,
                              use_kernel=True, interpret=True)
    Ar, zr = ref.armt_update_ref(m, wk, wv, wb, A, z)
    _close(A2, Ar, dtype)
    _close(z2, zr, dtype)


# ---------------------------------------------------------------- mamba scan
@pytest.mark.parametrize("B,T,dI,dS", [(1, 8, 16, 4), (2, 16, 24, 4),
                                       (2, 32, 64, 8)])
def test_mamba_scan(B, T, dI, dS):
    ks = jax.random.split(jax.random.PRNGKey(B * T + dI), 5)
    x = jax.random.normal(ks[0], (B, T, dI)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, dI)))
    Bt = jax.random.normal(ks[2], (B, T, dS)) * 0.5
    Ct = jax.random.normal(ks[3], (B, T, dS)) * 0.5
    A_log = jnp.log(jnp.tile(jnp.arange(1., dS + 1)[None], (dI, 1)))
    D = jnp.ones(dI)
    h0 = jax.random.normal(ks[4], (B, dI, dS)) * 0.1
    y, hT = ops.selective_scan_fused(x, dt, Bt, Ct, A_log, D, h0,
                                     use_kernel=True, interpret=True)
    yr, hr = ref.mamba_scan_ref(x, dt, Bt, Ct, A_log, D, h0)
    _close(y, yr, jnp.float32)
    _close(hT, hr, jnp.float32)


def test_model_attention_matches_kernel_ref():
    """The model's jnp attention path == the kernel oracle (same math).
    RoPE disabled so projections can be compared directly."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models.attention import attention, attn_param_init
    cfg = dataclasses.replace(get_smoke_config("h2o-danube-1.8b"),
                              use_rope=False, sliding_window=0)
    p = attn_param_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    # reproduce internals: project then compare sdpa vs kernel ref
    from repro.models.attention import _project_qkv
    q, k, v = _project_qkv(x, p, cfg)
    o_model = attention(x, p, cfg)
    # kernel layout is [N, H, T, hd]
    o_ref = ref.flash_attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                                    v.swapaxes(1, 2), causal=True)
    o_ref = o_ref.swapaxes(1, 2).reshape(2, 16, -1) @ p["wo"]
    np.testing.assert_allclose(np.asarray(o_model), np.asarray(o_ref),
                               atol=2e-5, rtol=1e-4)
