import os

# Tests run single-device (the dry-run forces 512 separately, in its own
# process). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_default_matmul_precision", "highest")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
