"""Roofline machinery: HLO flop counting with trip multipliers, collective
wire-byte formulas, shape parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import HloAnalyzer, shape_bytes, wire_bytes
from repro.roofline.model_math import model_flops, param_counts


def test_shape_bytes():
    assert shape_bytes("bf16[16,1024]{1,0}") == 16 * 1024 * 2
    assert shape_bytes("f32[8]") == 32
    assert shape_bytes("(f32[2,2], s32[3])") == 16 + 12
    assert shape_bytes("pred[7]") == 7


def test_wire_bytes_formulas():
    assert wire_bytes("all-reduce", 100, 4) == pytest.approx(150.0)
    assert wire_bytes("all-gather", 100, 4) == pytest.approx(75.0)
    assert wire_bytes("reduce-scatter", 100, 4) == pytest.approx(300.0)
    assert wire_bytes("collective-permute", 100, 4) == 100.0
    assert wire_bytes("all-reduce", 100, 1) == 0.0


def test_flops_count_scan_trips():
    def f(x, w):
        def step(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(step, x, None, length=7)
        return y
    x = jnp.zeros((64, 128))
    w = jnp.zeros((128, 128))
    hlo = jax.jit(f).lower(x, w).compile().as_text()
    a = HloAnalyzer(hlo, 1)
    assert a.flops() == pytest.approx(7 * 2 * 64 * 128 * 128)


def test_nested_scan_trips():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    x = jnp.zeros((16, 32))
    w = jnp.eye(32)
    hlo = jax.jit(f).lower(x, w).compile().as_text()
    a = HloAnalyzer(hlo, 1)
    assert a.flops() == pytest.approx(15 * 2 * 16 * 32 * 32)


def test_param_counts_moe_active():
    from repro.configs import get_config
    total, active = param_counts(get_config("kimi-k2-1t-a32b"))
    assert 0.9e12 < total < 1.3e12            # ~1T total
    assert 25e9 < active < 45e9               # ~32B active
    t2, a2 = param_counts(get_config("h2o-danube-1.8b"))
    assert t2 == a2                            # dense: all params active


def test_model_flops_kinds():
    from repro.configs import SHAPES, get_config
    cfg = get_config("h2o-danube-1.8b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    dc = model_flops(cfg, SHAPES["decode_32k"])
    assert tr > pf > dc > 0
    # train = 6ND with D = 4096*256 tokens
    n = param_counts(cfg)[1] - cfg.vocab * cfg.d_model
    assert tr == pytest.approx(6 * n * 4096 * 256)
