"""Serving-path consistency: prefill-via-forward == token-by-token decode,
ARMT flush at segment boundaries, both serve modes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import (decode_state_init, decode_step, flush_segment,
                          init_params)
from repro.serve import ServeEngine


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "falcon-mamba-7b",
                                  "qwen2-moe-a2.7b"])
def test_prefill_matches_decode(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # capacity drops depend on how many tokens are batched together
        # (prefill batches a whole segment, decode sees one token) — use a
        # dropless capacity factor so the schedules must agree exactly
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    seg = cfg.armt.segment_len if cfg.armt else 16
    P = 2 * seg + seg // 2                       # two full segments + tail
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 8, cfg.vocab)

    eng = ServeEngine(params, cfg, serve_mode="armt", schedule="diagonal",
                      max_len=P + 8)
    logits_a, _ = eng.prefill(prompts)

    # jit once — tracing decode_step anew per token is what used to make
    # this test dominate the tier-1 wall-clock
    step = jax.jit(lambda s, t: decode_step(params, cfg, s, t,
                                            serve_mode="armt"))
    flush = jax.jit(lambda s: flush_segment(params, cfg, s))
    st = decode_state_init(cfg, B, serve_mode="armt", max_len=P + 8,
                           dtype=jnp.float32)
    logits_b = None
    pos = 0
    for t in range(P):
        logits_b, st = step(st, prompts[:, t])
        pos += 1
        if cfg.armt and pos >= seg:
            st = flush(st)
            pos = 0
    rel = float(jnp.abs(logits_a - logits_b).max()
                / (jnp.abs(logits_b).max() + 1e-9))
    assert rel < 1e-3, f"{arch}: prefill/decode mismatch rel={rel}"
    assert bool((jnp.argmax(logits_a, -1) == jnp.argmax(logits_b, -1)).all())


def test_cache_mode_matches_full_forward():
    """'cache' decode over a prompt == full-attention forward logits."""
    from repro.models import forward_hidden, last_logits
    cfg = dataclasses.replace(get_smoke_config("h2o-danube-1.8b"), armt=None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, P = 2, 24
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 8, cfg.vocab)
    hidden, _ = forward_hidden(params, cfg, prompts, mode="full")
    want = last_logits(params, cfg, hidden)

    step = jax.jit(lambda s, t: decode_step(params, cfg, s, t,
                                            serve_mode="cache"))
    st = decode_state_init(cfg, B, serve_mode="cache", max_len=P + 4,
                           dtype=jnp.float32)
    got = None
    for t in range(P):
        got, st = step(st, prompts[:, t])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


def test_generate_shapes_and_determinism():
    cfg = get_smoke_config("h2o-danube-1.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 40), 8, cfg.vocab)
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=64)
    r1 = eng.generate(prompts, 8)
    r2 = eng.generate(prompts, 8)
    assert r1.tokens.shape == (2, 8)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)


def test_engine_rejects_armt_mode_without_recurrent_state():
    """Regression: serve_mode='armt' on an attention arch without cfg.armt
    used to silently fall back to seg_len=1024 — attention layers then never
    flush and prefill segments become disconnected contexts. It must raise.
    Pure-SSM archs (falcon-mamba) stay valid: their recurrence needs no
    ARMT config."""
    cfg = dataclasses.replace(get_smoke_config("h2o-danube-1.8b"), armt=None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="armt"):
        ServeEngine(params, cfg, serve_mode="armt", max_len=64)
    # cache mode on the same config stays valid
    ServeEngine(params, cfg, serve_mode="cache", max_len=64)
    with pytest.raises(ValueError, match="serve_mode"):
        ServeEngine(params, cfg, serve_mode="bogus", max_len=64)
    # pure-SSM: armt serving without an ARMT config is well-defined
    mcfg = dataclasses.replace(get_smoke_config("falcon-mamba-7b"), armt=None)
    mparams = init_params(mcfg, jax.random.PRNGKey(0))
    eng = ServeEngine(mparams, mcfg, serve_mode="armt", max_len=64)
    assert eng.seg_len == 64            # one chunk, no fake 1024 boundary


def test_armt_decode_state_is_constant_in_context():
    """Paper Fig. 1: ARMT serve state is O(1) in context length."""
    from repro.utils import tree_bytes
    cfg = get_smoke_config("h2o-danube-1.8b")
    s1 = jax.eval_shape(lambda: decode_state_init(
        cfg, 4, serve_mode="armt", max_len=32_768, dtype=jnp.float32))
    s2 = jax.eval_shape(lambda: decode_state_init(
        cfg, 4, serve_mode="armt", max_len=524_288, dtype=jnp.float32))
    assert tree_bytes(s1) == tree_bytes(s2)
    c1 = jax.eval_shape(lambda: decode_state_init(
        cfg, 4, serve_mode="cache", max_len=32_768, dtype=jnp.float32))
    c2 = jax.eval_shape(lambda: decode_state_init(
        cfg, 4, serve_mode="cache", max_len=524_288, dtype=jnp.float32))
    assert tree_bytes(c2) > 10 * tree_bytes(c1)
    assert tree_bytes(s1) < tree_bytes(c1)
