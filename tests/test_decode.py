"""Serving-path consistency: prefill-via-forward == token-by-token decode,
ARMT flush at segment boundaries, both serve modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import (decode_state_init, decode_step, flush_segment,
                          init_params)
from repro.serve import ServeEngine


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "falcon-mamba-7b",
                                  "qwen2-moe-a2.7b"])
def test_prefill_matches_decode(arch):
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # capacity drops depend on how many tokens are batched together
        # (prefill batches a whole segment, decode sees one token) — use a
        # dropless capacity factor so the schedules must agree exactly
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    seg = cfg.armt.segment_len if cfg.armt else 16
    P = 2 * seg + seg // 2                       # two full segments + tail
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 8, cfg.vocab)

    eng = ServeEngine(params, cfg, serve_mode="armt", schedule="diagonal",
                      max_len=P + 8)
    logits_a, _ = eng.prefill(prompts)

    st = decode_state_init(cfg, B, serve_mode="armt", max_len=P + 8,
                           dtype=jnp.float32)
    logits_b = None
    for t in range(P):
        logits_b, st = decode_step(params, cfg, st, prompts[:, t],
                                   serve_mode="armt")
        if cfg.armt and int(st["pos"]) >= seg:
            st = flush_segment(params, cfg, st)
    rel = float(jnp.abs(logits_a - logits_b).max()
                / (jnp.abs(logits_b).max() + 1e-9))
    assert rel < 1e-3, f"{arch}: prefill/decode mismatch rel={rel}"
    assert bool((jnp.argmax(logits_a, -1) == jnp.argmax(logits_b, -1)).all())


def test_cache_mode_matches_full_forward():
    """'cache' decode over a prompt == full-attention forward logits."""
    import dataclasses
    from repro.models import forward_hidden, last_logits
    cfg = dataclasses.replace(get_smoke_config("h2o-danube-1.8b"), armt=None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, P = 2, 24
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 8, cfg.vocab)
    hidden, _ = forward_hidden(params, cfg, prompts, mode="full")
    want = last_logits(params, cfg, hidden)

    st = decode_state_init(cfg, B, serve_mode="cache", max_len=P + 4,
                           dtype=jnp.float32)
    got = None
    for t in range(P):
        got, st = decode_step(params, cfg, st, prompts[:, t],
                              serve_mode="cache")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


def test_generate_shapes_and_determinism():
    cfg = get_smoke_config("h2o-danube-1.8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 40), 8, cfg.vocab)
    eng = ServeEngine(params, cfg, serve_mode="armt", max_len=64)
    r1 = eng.generate(prompts, 8)
    r2 = eng.generate(prompts, 8)
    assert r1.tokens.shape == (2, 8)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)


def test_armt_decode_state_is_constant_in_context():
    """Paper Fig. 1: ARMT serve state is O(1) in context length."""
    from repro.utils import tree_bytes
    cfg = get_smoke_config("h2o-danube-1.8b")
    s1 = jax.eval_shape(lambda: decode_state_init(
        cfg, 4, serve_mode="armt", max_len=32_768, dtype=jnp.float32))
    s2 = jax.eval_shape(lambda: decode_state_init(
        cfg, 4, serve_mode="armt", max_len=524_288, dtype=jnp.float32))
    assert tree_bytes(s1) == tree_bytes(s2)
    c1 = jax.eval_shape(lambda: decode_state_init(
        cfg, 4, serve_mode="cache", max_len=32_768, dtype=jnp.float32))
    c2 = jax.eval_shape(lambda: decode_state_init(
        cfg, 4, serve_mode="cache", max_len=524_288, dtype=jnp.float32))
    assert tree_bytes(c2) > 10 * tree_bytes(c1)
    assert tree_bytes(s1) < tree_bytes(c1)
