"""End-to-end behaviour: the fault-tolerant training loop learns, resumes
from checkpoints, and the needle task shows the ARMT memory actually carries
information across segments."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import lm_stream, needle_qa
from repro.optim import OptimConfig
from repro.train.loop import train_loop


def test_loss_decreases_lm():
    cfg = get_smoke_config("llama-1b-armt")
    ocfg = OptimConfig(lr=3e-3, total_steps=30, warmup_steps=3)
    data = lm_stream(cfg.vocab, 4, 64, seed=0)
    out = train_loop(cfg, ocfg, data, steps=30, schedule="sequential")
    losses = [h["loss"] for h in out["history"]]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_checkpoint_resume_continues(tmp_path):
    cfg = get_smoke_config("llama-1b-armt")
    ocfg = OptimConfig(lr=1e-3, total_steps=20, warmup_steps=2)
    data1 = lm_stream(cfg.vocab, 2, 64, seed=0)
    out1 = train_loop(cfg, ocfg, data1, steps=10, ckpt_dir=str(tmp_path),
                      ckpt_every=5, schedule="sequential")
    assert out1["last_step"] == 10
    # fresh process-equivalent: new loop resumes from step 10
    data2 = lm_stream(cfg.vocab, 2, 64, seed=0)
    out2 = train_loop(cfg, ocfg, data2, steps=15, ckpt_dir=str(tmp_path),
                      ckpt_every=5, schedule="sequential")
    steps = [h["step"] for h in out2["history"]]
    assert steps[0] == 10 and out2["last_step"] == 15
    # metrics were journaled
    lines = (tmp_path / "metrics.jsonl").read_text().strip().splitlines()
    assert len(lines) >= 15


@pytest.mark.slow
def test_needle_loss_improves_with_training():
    """Train the reduced ARMT on needle-QA where the needle sits in an
    *earlier segment* than the query — solvable only via memory."""
    cfg = get_smoke_config("llama-1b-armt")
    ocfg = OptimConfig(lr=3e-3, total_steps=60, warmup_steps=5,
                       weight_decay=0.0)
    data = needle_qa(cfg.vocab, 8, 64, seed=0, n_keys=4,
                     needle_region=(0.05, 0.4))
    out = train_loop(cfg, ocfg, data, steps=60, schedule="sequential")
    losses = [h["loss"] for h in out["history"]]
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.8, (
        losses[:5], losses[-5:])
