"""Optimizer: convergence, clipping, schedule, non-finite step skipping."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (OptimConfig, adamw_init, adamw_update,
                         clip_by_global_norm, global_norm, lr_schedule)


def test_adamw_converges_on_quadratic():
    ocfg = OptimConfig(lr=0.1, weight_decay=0.0, clip_norm=0,
                       warmup_steps=0, total_steps=200, min_lr_ratio=1.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params, ocfg)
    loss = lambda p: jnp.sum((p["w"] - 1.0) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, ocfg)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4


def test_lr_schedule_shape():
    ocfg = OptimConfig(lr=1.0, warmup_steps=10, total_steps=100,
                       min_lr_ratio=0.1)
    lrs = [float(lr_schedule(ocfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] < 0.2                      # warmup starts low
    assert abs(lrs[10] - 1.0) < 1e-5         # peak at warmup end
    assert abs(lrs[100] - 0.1) < 1e-3        # decays to min ratio
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))


def test_train_step_skips_nonfinite():
    from repro.configs import get_smoke_config
    from repro.train import init_train_state, make_train_step
    cfg = get_smoke_config("h2o-danube-1.8b")
    ocfg = OptimConfig(total_steps=10, warmup_steps=1)
    state = init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, ocfg, schedule="sequential"))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 8, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    # poison the params of one leaf -> loss becomes NaN -> step must skip
    bad = jax.tree_util.tree_map(lambda x: x, state)
    bad["params"]["embed"] = state["params"]["embed"].at[0, 0].set(jnp.nan)
    new_state, metrics = step(bad, batch)
    assert float(metrics["skipped"]) == 1.0
    # params unchanged (the skip kept the old values)
    np.testing.assert_array_equal(np.asarray(new_state["params"]["embed"]),
                                  np.asarray(bad["params"]["embed"]))
