"""Mesh-native serving (DESIGN.md §10) is *exact*: on 8 fake host devices,
greedy `generate`, continuous `serve()`, and prefix-cache / session resume
are token-identical to the single-device engine, and state-store blobs
round-trip across different mesh shapes (snapshot on 2x4, resume on 1
device, and the reverse).

The mesh checks run in one subprocess (XLA_FLAGS must be set before jax
imports) that prints one ``OK <name>`` marker per property; a timeout skips
with a clear message (compiling GSPMD programs on 8 fake CPU devices can
exceed constrained CI boxes — that is not a serving regression). The
subprocess test is ``slow``-marked like its sibling in
test_slot_sharding.py — the default tier-1 selection stays fast — and the
CI workflow *gates* it in a dedicated sharded-serving step that selects
``-m 'slow or not slow'``.

The mesh-spec parser and the sharding-fallback warnings (satellites of the
same PR) are plain single-device unit tests below.
"""
import logging
import subprocess
import sys

import pytest

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import dataclasses
import numpy as np
import jax
jax.config.update("jax_default_matmul_precision", "highest")
from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve import PrefixCache, Request, ServeEngine, SessionStore
from repro.launch.mesh import parse_mesh

# n_kv_heads=4 so kv heads divide model=4 (the smoke config's 2 would fall
# back to cache-sequence sharding — legal, but this test wants real TP)
cfg = dataclasses.replace(get_smoke_config("h2o-danube-1.8b"), n_kv_heads=4)
params = init_params(cfg, jax.random.PRNGKey(0))
seg = cfg.armt.segment_len
rng = np.random.default_rng(7)
MAXLEN, NEW = 256, 8

ref_eng = ServeEngine(params, cfg, serve_mode="armt", max_len=MAXLEN)
mesh = parse_mesh("data=2,model=4")
eng = ServeEngine(params, cfg, serve_mode="armt", max_len=MAXLEN, mesh=mesh)

# --- greedy generate: batch of 2, multi-segment prompt with tail ---------
prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 2 * seg + 5), 8,
                             cfg.vocab)
ref = ref_eng.generate(prompts, 12).tokens
assert (eng.generate(prompts, 12).tokens == ref).all()
print("OK generate")

# --- stage-sharded mesh: diagonal-as-pipeline prefill --------------------
eng_stage = ServeEngine(params, cfg, serve_mode="armt", max_len=MAXLEN,
                        mesh=parse_mesh("stage=2,model=4"))
assert (eng_stage.generate(prompts, 12).tokens == ref).all()
print("OK generate_stage")

# --- continuous serve(): mixed lengths/phases, more requests than slots --
reqs = [Request(req_id=f"r{i}",
                prompt=rng.integers(8, cfg.vocab, (L,)).astype(np.int32),
                max_new=5)
        for i, L in enumerate([2 * seg, seg + 3, 7, seg - 1])]
outs = {}
for ev in eng.serve(reqs, n_slots=2, chunk=3):
    outs.setdefault(ev.req_id, []).append(ev.token)
for r in reqs:
    want = ref_eng.generate(np.asarray(r.prompt)[None], 5).tokens[0]
    assert outs[r.req_id] == want.tolist(), r.req_id
print("OK serve")

# --- prefix cache on the mesh: partial hit and full-prefix hit -----------
cache = PrefixCache(seg, max_bytes=64 << 20)
eng_pc = ServeEngine(params, cfg, serve_mode="armt", max_len=MAXLEN,
                     mesh=mesh, prefix_cache=cache)
sys_p = rng.integers(8, cfg.vocab, (2 * seg,)).astype(np.int32)
p1 = np.concatenate([sys_p, rng.integers(8, cfg.vocab, (5,)).astype(np.int32)])
r1 = eng_pc.generate(p1[None], NEW)            # cold: fills the cache
r2 = eng_pc.generate(p1[None], NEW)            # partial-tail hit
r3 = eng_pc.generate(sys_p[None], NEW)         # exact full-prefix hit
assert r1.cached_segments == 0 and r2.cached_segments == 2 \
    and r3.cached_segments == 2
assert (r2.tokens == ref_eng.generate(p1[None], NEW).tokens).all()
assert (r3.tokens == ref_eng.generate(sys_p[None], NEW).tokens).all()
print("OK prefix_cache")

# --- cross-mesh session restore: 2x4 -> 1 device and 1 device -> 2x4 -----
t1 = rng.integers(8, cfg.vocab, (seg + 3,)).astype(np.int32)
t2 = rng.integers(8, cfg.vocab, (seg // 2,)).astype(np.int32)
store_ref = SessionStore(max_bytes=64 << 20)
ref_s = ServeEngine(params, cfg, serve_mode="armt", max_len=MAXLEN,
                    session_store=store_ref)
b1 = ref_s.generate(t1[None], NEW, session_id="s")
b2 = ref_s.generate(t2[None], NEW, session_id="s")

store = SessionStore(max_bytes=64 << 20)
m1 = ServeEngine(params, cfg, serve_mode="armt", max_len=MAXLEN, mesh=mesh,
                 session_store=store)                 # capture on 2x4
a1 = m1.generate(t1[None], NEW, session_id="s")
d1 = ServeEngine(params, cfg, serve_mode="armt", max_len=MAXLEN,
                 session_store=store)                 # resume on 1 device
a2 = d1.generate(t2[None], NEW, session_id="s")
assert (a1.tokens == b1.tokens).all()
assert a2.resumed and (a2.tokens == b2.tokens).all()
print("OK session_2x4_to_1dev")

store2 = SessionStore(max_bytes=64 << 20)
s1 = ServeEngine(params, cfg, serve_mode="armt", max_len=MAXLEN,
                 session_store=store2)                # capture on 1 device
c1 = s1.generate(t1[None], NEW, session_id="z")
m2 = ServeEngine(params, cfg, serve_mode="armt", max_len=MAXLEN, mesh=mesh,
                 session_store=store2)                # resume on 2x4
c2 = m2.generate(t2[None], NEW, session_id="z")
assert c2.resumed and (c2.tokens == b2.tokens).all()
print("OK session_1dev_to_2x4")

# --- scheduler sessions through the mesh engine --------------------------
store3 = SessionStore(max_bytes=64 << 20)
eng_s = ServeEngine(params, cfg, serve_mode="armt", max_len=MAXLEN,
                    mesh=mesh, session_store=store3)
outs = {}
for ev in eng_s.serve([Request("q", t1, NEW, session_id="w")], n_slots=2,
                      chunk=3):
    outs.setdefault(ev.req_id, []).append(ev.token)
for ev in eng_s.serve([Request("q2", t2, NEW, session_id="w")], n_slots=2,
                      chunk=3):
    outs.setdefault(ev.req_id, []).append(ev.token)
assert outs["q"] == b1.tokens[0].tolist()
assert outs["q2"] == b2.tokens[0].tolist()
print("OK scheduler_sessions")
"""

_MARKERS = ("generate", "generate_stage", "serve", "prefix_cache",
            "session_2x4_to_1dev", "session_1dev_to_2x4",
            "scheduler_sessions")


@pytest.mark.slow
def test_sharded_serving_token_identical():
    try:
        r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                           capture_output=True, text=True, timeout=600,
                           env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                "HOME": "/root"})
    except subprocess.TimeoutExpired:
        pytest.skip("sharded-serve subprocess exceeded 600s: environment "
                    "too constrained to compile the 8-fake-device GSPMD "
                    "programs — exactness is asserted whenever the compile "
                    "finishes (CI runs this as a dedicated step)")
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-2000:])
    for m in _MARKERS:
        assert f"OK {m}" in r.stdout, (m, r.stdout[-1000:])


# ---------------------------------------------------------------------------
# Single-device satellites: mesh-spec parsing + fallback warnings
# ---------------------------------------------------------------------------

def test_parse_mesh_specs():
    import jax
    from repro.launch.mesh import parse_mesh
    dev = jax.devices()
    m = parse_mesh("data=1,model=1", devices=dev[:1])
    assert dict(m.shape) == {"data": 1, "model": 1}
    m = parse_mesh("data,model=1", devices=dev[:1])   # open axis absorbs
    assert dict(m.shape) == {"data": 1, "model": 1}
    with pytest.raises(ValueError, match="unknown mesh axis"):
        parse_mesh("banana=2", devices=dev[:1])
    with pytest.raises(ValueError, match="at most one axis"):
        parse_mesh("data,model", devices=dev[:1])
    with pytest.raises(ValueError, match="duplicate"):
        parse_mesh("data=1,data=1", devices=dev[:1])
    with pytest.raises(ValueError, match="device"):
        parse_mesh("data=64,model=2", devices=dev[:1])
    # underfill is an error, not a silent subset (device_count provenance)
    with pytest.raises(ValueError, match="open axis"):
        parse_mesh("data=1", devices=dev[:1] * 2)
    with pytest.raises(ValueError, match=">= 1"):
        parse_mesh("data=0", devices=dev[:1])
    with pytest.raises(ValueError, match="empty"):
        parse_mesh(" , ", devices=dev[:1])


class _FakeMesh:
    def __init__(self, shape):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


def test_sharding_fallback_warnings(caplog):
    """A dim a rule wanted to shard that does not divide its axis emits one
    structured warning line naming the leaf/dim (and only one — deduped)."""
    from repro.parallel import sharding as shd
    shd.reset_fallback_warnings()
    with caplog.at_level(logging.WARNING, logger="repro.parallel.sharding"):
        spec = shd.param_leaf_spec(["pattern", "attn", "wq"], (30, 30), 16)
    assert spec == shd.P(None, None)
    recs = [r for r in caplog.records if "sharding-fallback" in r.getMessage()]
    assert len(recs) == 1
    msg = recs[0].getMessage()
    assert "leaf=pattern.attn.wq" in msg and "dim=1" in msg \
        and "axis=model" in msg and "axis_size=16" in msg
    # dedup: the same fallback does not log twice
    with caplog.at_level(logging.WARNING, logger="repro.parallel.sharding"):
        shd.param_leaf_spec(["pattern", "attn", "wq"], (30, 30), 16)
    recs = [r for r in caplog.records if "sharding-fallback" in r.getMessage()]
    assert len(recs) == 1


def test_batch_axes_warning_only_above_one(caplog):
    """batch=1 replication (scheduler admission) is by design and silent;
    batch>1 that can't fill the dp axes warns."""
    from repro.parallel import sharding as shd
    shd.reset_fallback_warnings()
    mesh = _FakeMesh({"data": 4, "model": 2})
    with caplog.at_level(logging.WARNING, logger="repro.parallel.sharding"):
        assert shd.batch_axes(mesh, 1, leaf="admission") is None
    assert not [r for r in caplog.records
                if "sharding-fallback" in r.getMessage()]
    with caplog.at_level(logging.WARNING, logger="repro.parallel.sharding"):
        assert shd.batch_axes(mesh, 3, leaf="pool") is None
    recs = [r for r in caplog.records if "sharding-fallback" in r.getMessage()]
    assert len(recs) == 1 and "leaf=pool" in recs[0].getMessage()


def test_decode_state_specs_per_slot_pos():
    """The per-slot pos vector shards with the slots; a scalar pos stays
    replicated (spec derivation, no devices needed)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.models import decode_state_shapes
    from repro.parallel import sharding as shd

    cfg = get_smoke_config("h2o-danube-1.8b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for per_slot, want in ((True, P("data")), (False, P())):
        shapes = decode_state_shapes(cfg, 4, serve_mode="armt", max_len=64,
                                     dtype=jnp.float32, per_slot_pos=per_slot)
        specs = shd.decode_state_specs(shapes, mesh, 4)
        assert specs["pos"].spec == want, (per_slot, specs["pos"].spec)
