"""Int8 error-feedback gradient compression (optim/compression.py)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test extra ([test] in pyproject)
from hypothesis import given, settings, strategies as st

from repro.optim.compression import (dequantize_int8, ef_compress,
                                     quantize_int8, wire_bytes_ratio)


@given(st.integers(0, 20), st.integers(3, 700))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_error_bound(seed, n):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, n)) * 3.0
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s, n)
    # per-tile max-abs scaling: error <= scale/2 <= max|tile|/254
    err = np.abs(np.asarray(x) - np.asarray(y))
    bound = np.asarray(s).max() * 0.51
    assert err.max() <= bound + 1e-6


def test_error_feedback_unbiased_over_steps():
    """With EF, the *accumulated* applied gradient tracks the true sum —
    the defining property that makes compression safe for optimization."""
    key = jax.random.PRNGKey(0)
    g_true = jax.random.normal(key, (8, 513))
    err = jnp.zeros((8, 520), jnp.float32)[:, :513] * 0  # match padding shape
    err = jnp.zeros_like(g_true)
    applied = jnp.zeros_like(g_true)
    for i in range(20):
        g_hat, err = ef_compress(g_true, err)
        applied = applied + g_hat
    # mean applied per step ~ g_true (error stays bounded, doesn't accumulate)
    drift = np.abs(np.asarray(applied / 20 - g_true)).max()
    assert drift < np.abs(np.asarray(g_true)).max() * 0.01


def test_wire_ratio():
    assert wire_bytes_ratio(2) < 0.3     # ~4x reduction across 2 pods


_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.optim.compression import compressed_psum

mesh = jax.make_mesh((4,), ("pod",))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 256))

@jax.jit
def f(x):
    fn = shard_map(lambda xx: compressed_psum(xx[0], "pod"),
                   mesh=mesh, in_specs=P("pod"), out_specs=P(),
                   check_rep=False)
    return fn(x)

got = f(x)
want = x.sum(0)
rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
print("REL", rel)
assert rel < 0.02, rel
"""


def test_compressed_psum_shard_map():
    r = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "REL" in r.stdout and r.returncode == 0, r.stderr[-1500:]
