"""Original RMT baseline: the paper's Limitation 1 made precise — the
diagonal schedule violates RMT's inter-layer dependency, while the PRMT
executors remain valid; and the RMT executor itself works sequentially."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test extra ([test] in pyproject)
from hypothesis import given, settings, strategies as st

from repro.core import StackLayout, diagonal_groups, validate_schedule
from repro.core.rmt import diagonal_violates_rmt, rmt_dependencies, run_rmt


@given(st.integers(2, 12), st.integers(2, 12))
@settings(max_examples=20, deadline=None)
def test_diagonal_inapplicable_to_rmt(S, L):
    """Paper Limitation 1: for any L >= 2, diagonal batching breaks RMT."""
    assert diagonal_violates_rmt(S, L)


def test_diagonal_valid_for_single_layer_rmt():
    """L == 1: RMT degenerates to PRMT; the diagonal schedule is valid."""
    assert not diagonal_violates_rmt(8, 1)


def test_rmt_dependency_structure():
    assert rmt_dependencies(0, 0, 4) == []
    assert (3, 3) in rmt_dependencies(4, 0, 4)      # memory from final layer
    assert (4, 1) in rmt_dependencies(4, 2, 4)


def test_run_rmt_carries_memory():
    """RMT memory actually transports information across segments: zeroing
    the first segment's tokens must still influence later outputs less than
    zeroing the memory does."""
    layout = StackLayout(prelude=(), pattern=("a",), n_super=2)

    def apply_block(t, p, x, st):
        # position-mixing block (attention stand-in): tokens see the memory
        return jnp.tanh(x @ p["w"] + x.mean(axis=1, keepdims=True)), st

    D, M, B, T, S = 8, 2, 1, 4, 3
    key = jax.random.PRNGKey(0)
    params = {"prelude": (),
              "pattern": ({"w": jax.random.normal(key, (2, D, D)) * 0.5},)}
    mem0 = jax.random.normal(jax.random.PRNGKey(1), (B, M, D))
    segs = jax.random.normal(jax.random.PRNGKey(2), (S, B, T, D))
    ys, fin = run_rmt(layout, params, mem0, segs, apply_block)
    assert ys.shape == (S, B, T, D)
    assert fin.shape == (B, M, D)
    # memory dependence: different mem0 -> different final segment output
    ys2, _ = run_rmt(layout, params, mem0 + 1.0, segs, apply_block)
    assert float(jnp.abs(ys[-1] - ys2[-1]).max()) > 1e-4
    # tokens of segment 0 also reach segment 2 through memory
    segs_z = segs.at[0].set(0.0)
    ys3, _ = run_rmt(layout, params, mem0, segs_z, apply_block)
    assert float(jnp.abs(ys[-1] - ys3[-1]).max()) > 1e-6
