"""Model-level schedule equivalence across all families (paper Table 2
metric: relative Frobenius error of logits) + fp64 exactness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import forward_hidden, init_params
from repro.models.layers import norm
from repro.models.model import _head_matmul

FAMS = ["h2o-danube-1.8b", "qwen2-moe-a2.7b", "kimi-k2-1t-a32b",
        "jamba-1.5-large-398b", "falcon-mamba-7b", "whisper-medium",
        "chameleon-34b"]


def _logits(params, cfg, h):
    hn = norm(cfg.norm, h, params["final_norm"])
    return _head_matmul(params, cfg, hn).astype(jnp.float32)


@pytest.mark.parametrize("arch", [
    # jamba compiles both executors over an 8-type pattern — the slowest
    # single cell of the suite; CI still runs it (-m "slow or not slow")
    pytest.param(a, marks=pytest.mark.slow)
    if a == "jamba-1.5-large-398b" else a
    for a in FAMS])
def test_logits_relative_error_below_paper_bound(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 8, cfg.vocab)
    kw = {}
    if cfg.encoder is not None:
        kw["enc_frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (2, cfg.encoder.n_frames, cfg.d_model))
    seg = cfg.armt.segment_len if cfg.armt else 16
    hs, _ = forward_hidden(params, cfg, toks, schedule="sequential",
                           seg_len=seg, **kw)
    hd, _ = forward_hidden(params, cfg, toks, schedule="diagonal",
                           seg_len=seg, **kw)
    ls, ld = _logits(params, cfg, hs), _logits(params, cfg, hd)
    rel = float(jnp.linalg.norm(ls - ld) / jnp.linalg.norm(ls))
    # paper Table 2 reports <= 2% for their fp16 CUDA kernels; our fp32
    # reordering drift is orders of magnitude smaller
    assert rel < 2e-3, f"{arch}: rel logits err {rel}"


_FP64_SCRIPT = r"""
import jax
jax.config.update("jax_enable_x64", True)
import dataclasses, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models import init_params, forward_hidden
cfg = dataclasses.replace(get_smoke_config("h2o-danube-1.8b"), dtype="float64")
params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float64)
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 8, cfg.vocab)
hs, _ = forward_hidden(params, cfg, toks, schedule="sequential")
hdg, _ = forward_hidden(params, cfg, toks, schedule="diagonal")
d = float(jnp.abs(hs - hdg).max())
print("MAXDIFF", d)
assert d < 1e-10, d
"""


def test_fp64_exactness_danube():
    """In fp64 the reordering is exact to machine epsilon — proves the
    executors compute the *same* function (paper: 'preserving exact
    recurrence'). Runs in a subprocess because x64 must be set at startup."""
    import subprocess, sys
    r = subprocess.run([sys.executable, "-c", _FP64_SCRIPT],
                       capture_output=True, text=True, timeout=420,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert "MAXDIFF" in r.stdout and r.returncode == 0, r.stderr[-2000:]


def test_full_mode_matches_single_segment():
    """mode='full' on one segment == segmented with seg_len = total (the
    memoryless base transformer)."""
    cfg = get_smoke_config("h2o-danube-1.8b")
    cfg = dataclasses.replace(cfg, armt=None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 8, cfg.vocab)
    h_full, _ = forward_hidden(params, cfg, toks, mode="full")
    h_seg, _ = forward_hidden(params, cfg, toks, mode="segmented", seg_len=32)
    np.testing.assert_allclose(np.asarray(h_full[0]), np.asarray(h_seg[0]),
                               atol=1e-5)
