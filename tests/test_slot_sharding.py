"""Slot-sharded ('diagonal-as-pipeline') execution must be numerically
identical to the unsharded sequential schedule — run on 8 fake devices.

The subprocess compiles a GSPMD program on 8 fake CPU devices, which can
take minutes on constrained CI runners — the config is shrunk to the
smallest mesh that still shards slots (stage=2, n_layers=2), and a timeout
skips with a clear message instead of failing the suite (the de-flake is
deliberate: a slow box is not a numerics regression)."""
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models import init_params, forward_hidden
from repro.parallel import sharding as shd

# smallest slot-shardable stack: 2 layers over stage=2 (was 4/4 — the
# subprocess timed out in constrained envs, CHANGES PR 2)
cfg = dataclasses.replace(get_smoke_config("h2o-danube-1.8b"), n_layers=2)
params = init_params(cfg, jax.random.PRNGKey(0))
# 2 segments: the exactness regime (longer random-init ARMT recurrences
# chaotically amplify reduction-order noise — see EXPERIMENTS.md §1.2)
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 8, cfg.vocab)

# reference: single-device sequential
ref, _ = forward_hidden(params, cfg, toks, schedule="sequential")

mesh = jax.make_mesh((2, 2), ("data", "stage"))
slot_spec = P("stage", "data", None, None)
with mesh:
    pspecs = shd.param_specs(
        jax.eval_shape(lambda: params), mesh, stacked_axis="stage")
    p_sharded = jax.tree_util.tree_map(jax.device_put, params, pspecs)
    t_sharded = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
    fwd = jax.jit(lambda p, t: forward_hidden(
        p, cfg, t, schedule="diagonal", slot_spec=slot_spec)[0])
    got = fwd(p_sharded, t_sharded)

d = float(jnp.abs(jnp.asarray(got) - jnp.asarray(ref)).max())
print("MAXDIFF", d)
assert d < 2e-3, d
"""


@pytest.mark.slow
def test_slot_sharded_diagonal_matches_sequential():
    try:
        r = subprocess.run([sys.executable, "-c", _SCRIPT],
                           capture_output=True, text=True, timeout=600,
                           env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    except subprocess.TimeoutExpired:
        pytest.skip("slot-sharding subprocess exceeded 600s: this "
                    "environment is too constrained to compile the 8-fake-"
                    "device GSPMD program — not a numerics failure (the "
                    "equivalence itself is asserted whenever the compile "
                    "finishes)")
    assert "MAXDIFF" in r.stdout and r.returncode == 0, \
        (r.stdout[-500:], r.stderr[-1500:])
